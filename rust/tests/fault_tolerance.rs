//! Chaos matrix for the fault plane + supervisor (NUMERICS.md Rule 5):
//!
//! * every fault kind × world {1, 2, 4} × threads {1, 8} × async on/off:
//!   the supervised run recovers and its final state is **bitwise
//!   identical** to an uninterrupted run of the same shape;
//! * sticky rank death exhausts retries, the world shrinks W→W−1, and
//!   the recovered run is bitwise identical to a fresh W−1 run restored
//!   from the same checkpoint;
//! * an injected stream stall becomes a *named* watchdog error within
//!   the configured timeout — never a hang;
//! * corrupted checkpoint generations are rejected by CRC at recovery
//!   and the supervisor falls back a generation;
//! * the seeded probabilistic mode is reproducible from its spec string.
//!
//! Each supervised run writes its event log under `target/chaos-logs/`
//! so CI can upload the logs when the job fails.

use std::path::PathBuf;

use anyhow::Result;
use llmq::collectives::memcpy::PIPELINE_BLOCK;
use llmq::exec;
use llmq::fault::{self, FaultPlane};
use llmq::optim::fused::{fused_step_async, HostStep};
use llmq::optim::{AdamWParams, MomentsMode};
use llmq::precision::{round_to_bf16, CounterRng};
use llmq::train::checkpoint;
use llmq::train::supervisor::{Event, Supervised, Supervisor, SupervisorCfg};
use llmq::train::StepWorkspace;
use llmq::util::par;

/// Non-block-aligned, divisible by every world in the matrix (1, 2, 4).
const N: usize = PIPELINE_BLOCK + 128;
/// ZeRO-1 shard count baked into the AdamW SR counter layout — pinned
/// independently of the collective world so W→W−1 recovery replays the
/// exact same per-element counters.
const OPT_WORLD: usize = 4;

/// A `Supervised` workload over the fused optimizer-step pipeline: the
/// same state tuple the trainer checkpoints, minus the model forward
/// (gradients are a pure function of the step), so the chaos matrix
/// runs without artifact files.
struct FusedWorkload {
    world: usize,
    threads: usize,
    async_on: bool,
    streams: usize,
    step: u32,
    counter: u32,
    p: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    ws: StepWorkspace,
}

impl FusedWorkload {
    fn new(world: usize, threads: usize, async_on: bool, streams: usize) -> Self {
        let p = (0..N)
            .map(|i| round_to_bf16(0.02 * (i % 101) as f32 - 1.0))
            .collect();
        let m = (0..N)
            .map(|i| round_to_bf16(0.001 * (i % 13) as f32 - 0.006))
            .collect();
        let v = (0..N).map(|i| round_to_bf16(1e-4 * (i % 7) as f32)).collect();
        Self {
            world,
            threads,
            async_on,
            streams,
            step: 0,
            counter: 1,
            p,
            m,
            v,
            ws: StepWorkspace::new(world, N),
        }
    }

    /// Deterministic per-(step, device) gradients — replay after
    /// recovery feeds the retried step exactly what the failed attempt
    /// saw.
    fn fill_grads(&mut self, step: u32) {
        let rng = CounterRng::new(0xFA01 ^ step);
        for (d, g) in self.ws.dev_grads.iter_mut().enumerate() {
            for (i, x) in g.iter_mut().enumerate() {
                *x = round_to_bf16((rng.next_f32((d * N + i) as u32) - 0.5) * 0.08);
            }
        }
    }

    fn bits(&self) -> (Vec<u32>, Vec<u32>, Vec<u32>, u32, u32) {
        let b = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        (b(&self.p), b(&self.m), b(&self.v), self.step, self.counter)
    }
}

impl Supervised for FusedWorkload {
    fn world(&self) -> usize {
        self.world
    }

    fn step(&self) -> u32 {
        self.step
    }

    fn run_step(&mut self) -> Result<()> {
        let step = self.step + 1;
        // mirror Trainer::step_impl: announce the step, fire rank sites
        fault::set_step(step);
        for rank in 0..self.world {
            fault::step_site(rank, step);
        }
        self.ws.ensure(self.world, N); // repairs unwind damage on retry
        self.ws.begin_step();
        self.fill_grads(step);
        let hs = HostStep {
            hp: AdamWParams::default(),
            lr: 3e-4,
            grad_clip: 1.0,
            step,
            counter: self.counter,
            seed: 9,
            n_micro: 2 * self.world,
            opt_world: OPT_WORLD,
            moments: MomentsMode::Fp32,
        };
        let (ws, p, m, v) = (&mut self.ws, &mut self.p, &mut self.m, &mut self.v);
        par::with_threads(self.threads, || {
            exec::with_async(self.async_on, || {
                exec::with_streams(self.streams, || {
                    fused_step_async(ws, p, m, v, &hs);
                })
            })
        });
        // commit after success, like the trainer
        self.step = step;
        self.counter = self.counter.wrapping_add(3 * N as u32);
        Ok(())
    }

    fn encode_checkpoint(&self) -> Vec<u8> {
        checkpoint::encode(
            self.step,
            self.counter,
            self.world as u32,
            &self.p,
            &self.m,
            &self.v,
        )
    }

    fn restore_checkpoint(&mut self, bytes: &[u8]) -> Result<()> {
        let (step, counter) =
            checkpoint::decode_into(bytes, &mut self.p, &mut self.m, &mut self.v)?;
        self.step = step;
        self.counter = counter;
        Ok(())
    }

    fn reshard(&mut self, new_world: usize) -> Result<()> {
        anyhow::ensure!(N % new_world == 0, "world must divide n");
        self.world = new_world;
        self.ws.ensure(new_world, N);
        Ok(())
    }
}

fn chaos_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("llmq-chaos-{tag}-{}", std::process::id()))
}

fn sup_cfg(tag: &str) -> SupervisorCfg {
    let dir = chaos_dir(tag);
    let _ = std::fs::remove_dir_all(&dir);
    SupervisorCfg {
        backoff_ms: 0,
        keep_last: 4,
        ckpt_dir: dir,
        ..SupervisorCfg::default()
    }
}

/// Write the run's event log where CI collects chaos artifacts.
fn log_events(label: &str, events: &[Event]) {
    let path = PathBuf::from("target")
        .join("chaos-logs")
        .join(format!("{label}.log"));
    let _ = llmq::train::supervisor::write_event_log(&path, events);
}

/// An uninterrupted run of the same shape, driven without a supervisor.
fn reference(world: usize, threads: usize, async_on: bool, streams: usize, steps: u32) -> FusedWorkload {
    let mut w = FusedWorkload::new(world, threads, async_on, streams);
    for _ in 0..steps {
        w.run_step().unwrap();
    }
    w
}

/// The acceptance matrix: fault kind × world × threads × async; the
/// recovered run must be bitwise identical to the uninterrupted one.
#[test]
fn chaos_matrix_recovered_equals_uninterrupted() {
    const STEPS: u32 = 5;
    for world in [1usize, 2, 4] {
        // the uninterrupted reference is mode-invariant (Rule 4), so one
        // per world pins every (threads, async) cell at once
        let reference = reference(world, 1, false, 1, STEPS).bits();
        for threads in [1usize, 8] {
            for async_on in [false, true] {
                let streams = if async_on { 2 } else { 1 };
                let cells: [(&str, String, bool); 5] = [
                    (
                        "step-crash",
                        format!("rank{}:step3:crash", world - 1),
                        true,
                    ),
                    ("exec-crash", "rank0:step2:crash:exec".into(), true),
                    (
                        "collective-crash",
                        "rank0:step4:crash:collective".into(),
                        true,
                    ),
                    ("ckpt-io-error", "rank0:step2:io-error".into(), false),
                    (
                        "ckpt-corrupt-fallback",
                        "rank0:step3:corrupt-checkpoint;rank0:step4:crash".into(),
                        true,
                    ),
                ];
                for (tag, program, expect_failures) in cells {
                    let label = format!("{tag}-w{world}-t{threads}-a{async_on}");
                    let plane = FaultPlane::from_program(&program).unwrap();
                    let mut w = FusedWorkload::new(world, threads, async_on, streams);
                    let report = fault::with_plane(&plane, || {
                        Supervisor::new(sup_cfg(&label)).run(&mut w, STEPS)
                    });
                    log_events(&label, &report.events);
                    assert!(report.ok(), "{label}: {:?}", report.error);
                    assert_eq!(report.final_step, STEPS, "{label}");
                    if expect_failures {
                        assert!(report.failures > 0, "{label}: fault never fired");
                        assert!(
                            report
                                .events
                                .iter()
                                .any(|e| matches!(e, Event::Recovered { .. })),
                            "{label}: no recovery event"
                        );
                    } else {
                        assert!(
                            report
                                .events
                                .iter()
                                .any(|e| matches!(e, Event::CheckpointFailed { .. })),
                            "{label}: io-error save should surface as an event"
                        );
                    }
                    if tag == "ckpt-corrupt-fallback" {
                        assert!(
                            report
                                .events
                                .iter()
                                .any(|e| matches!(e, Event::CheckpointRejected { .. })),
                            "{label}: corrupt generation must be rejected by CRC"
                        );
                    }
                    assert_eq!(
                        w.bits(),
                        reference,
                        "{label}: recovered run is not bitwise identical"
                    );
                    let _ = std::fs::remove_dir_all(chaos_dir(&label));
                }
            }
        }
    }
}

/// Sticky rank death: retries exhaust, the supervisor reshards W→W−1,
/// and the result is bitwise identical to a fresh W−1 run restored from
/// the same generation.
#[test]
fn sticky_rank_death_shrinks_world_bitwise() {
    const STEPS: u32 = 5;
    let plane = FaultPlane::from_program("rank1:step3:crash:sticky").unwrap();
    let label = "sticky-shrink";
    let mut w = FusedWorkload::new(2, 8, true, 2);
    let cfg = SupervisorCfg {
        max_retries: 1,
        ..sup_cfg(label)
    };
    let report = fault::with_plane(&plane, || Supervisor::new(cfg).run(&mut w, STEPS));
    log_events(label, &report.events);
    assert!(report.ok(), "{:?}", report.error);
    assert_eq!(report.shrinks, 1);
    assert_eq!(report.final_world, 1);
    assert!(report
        .events
        .iter()
        .any(|e| matches!(e, Event::WorldShrunk { from: 2, to: 1 })));

    // Fresh W−1 reference: world 2 up to the last good generation
    // (step 2 — the crash kills every attempt of step 3), then reshard
    // to 1 and replay. The supervised run restored from the step-2
    // generation, so equality here *is* the Rule 5 reshard pin.
    let mut r = FusedWorkload::new(2, 8, true, 2);
    r.run_step().unwrap();
    r.run_step().unwrap();
    let blob = r.encode_checkpoint();
    let mut fresh = FusedWorkload::new(1, 8, true, 2);
    fresh.restore_checkpoint(&blob).unwrap();
    for _ in fresh.step..STEPS {
        fresh.run_step().unwrap();
    }
    assert_eq!(
        w.bits(),
        fresh.bits(),
        "W→W−1 recovery must equal a fresh W−1 run from the same checkpoint"
    );
    let _ = std::fs::remove_dir_all(chaos_dir(label));
}

/// An injected stream stall must surface as a *named* watchdog error
/// within the configured timeout — never a hang — and the supervised
/// retry must still land bitwise on the uninterrupted result.
#[test]
fn stall_becomes_named_watchdog_error_and_recovers() {
    const STEPS: u32 = 4;
    for async_on in [true, false] {
        let label = format!("stall-watchdog-a{async_on}");
        let plane = FaultPlane::from_program("rank0:step2:stall").unwrap();
        let mut w = FusedWorkload::new(1, 2, async_on, 2);
        let cfg = SupervisorCfg {
            watchdog_ms: Some(100),
            ..sup_cfg(&label)
        };
        let t0 = std::time::Instant::now();
        let report = fault::with_plane(&plane, || Supervisor::new(cfg).run(&mut w, STEPS));
        log_events(&label, &report.events);
        assert!(report.ok(), "{label}: {:?}", report.error);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(20),
            "{label}: stall was not cancelled promptly"
        );
        // the named error must carry the stream-program state dump
        let named = report.events.iter().any(|e| {
            matches!(e, Event::RankFailure { reason, .. }
                     if reason.contains("watchdog") && reason.contains("queue depths"))
        });
        assert!(
            named,
            "{label}: stall must surface as a named watchdog error; events:\n{}",
            llmq::train::supervisor::render_events(&report.events)
        );
        let reference = reference(1, 2, async_on, 2, STEPS);
        assert_eq!(w.bits(), reference.bits(), "{label}");
        let _ = std::fs::remove_dir_all(chaos_dir(&label));
    }
}

/// Slow-collective perturbs the schedule, never the numbers, and needs
/// no recovery at all.
#[test]
fn slow_collective_is_numerically_transparent() {
    const STEPS: u32 = 3;
    let label = "slow-collective";
    let plane = FaultPlane::from_program("prob:p1.0:seed3:slow-collective").unwrap();
    let mut w = FusedWorkload::new(2, 8, true, 2);
    let report = fault::with_plane(&plane, || Supervisor::new(sup_cfg(label)).run(&mut w, STEPS));
    log_events(label, &report.events);
    assert!(report.ok(), "{:?}", report.error);
    assert_eq!(report.failures, 0, "slow-collective must not fail steps");
    assert_eq!(w.bits(), reference(2, 8, true, 2, STEPS).bits());
    let _ = std::fs::remove_dir_all(chaos_dir(label));
}

/// The seeded probabilistic mode is a pure function of its spec string:
/// two runs with the same seed fail at the same points and land on the
/// same bits; the bits also match the uninterrupted reference.
#[test]
fn seeded_chaos_sweep_is_reproducible() {
    const STEPS: u32 = 8;
    // Pick the first seed whose deterministic draws fire at least once
    // inside the run's (rank, step) window — the choice is itself a pure
    // function of the grammar, so the test can never go quietly fault-free.
    let seed = (1u32..200)
        .find(|s| {
            let probe =
                FaultPlane::from_program(&format!("prob:p0.2:seed{s}:crash")).unwrap();
            (1..=STEPS).any(|step| {
                (0..2usize).any(|rank| {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        probe.step_site(rank, step)
                    }))
                    .is_err()
                })
            })
        })
        .expect("some seed in 1..200 fires at p=0.2 over 16 sites");
    let program = format!("prob:p0.2:seed{seed}:crash");
    let run = |tag: &str| {
        let plane = FaultPlane::from_program(&program).unwrap();
        let mut w = FusedWorkload::new(2, 2, true, 2);
        let report =
            fault::with_plane(&plane, || Supervisor::new(sup_cfg(tag)).run(&mut w, STEPS));
        log_events(tag, &report.events);
        assert!(report.ok(), "{tag}: {:?}", report.error);
        let _ = std::fs::remove_dir_all(chaos_dir(tag));
        (report.failures, plane.injections().len(), w.bits())
    };
    let (fail_a, inj_a, bits_a) = run("seeded-a");
    let (fail_b, inj_b, bits_b) = run("seeded-b");
    assert!(fail_a > 0, "chosen seed {seed} must fire in the run window");
    assert_eq!(fail_a, fail_b, "same seed, same failures");
    assert_eq!(inj_a, inj_b, "same seed, same injections");
    assert_eq!(bits_a, bits_b, "same seed, same bits");
    assert_eq!(bits_a, reference(2, 2, true, 2, STEPS).bits());
}

/// Supervised resume across process "restarts": run half the steps,
/// drop the workload, rebuild from the on-disk generation, finish — the
/// composite equals the straight run.
#[test]
fn resume_from_disk_generation_is_bitwise() {
    const STEPS: u32 = 6;
    let label = "resume";
    let cfg = sup_cfg(label);
    let mut w = FusedWorkload::new(2, 1, true, 2);
    let report = Supervisor::new(cfg.clone()).run(&mut w, 3);
    assert!(report.ok());
    drop(w);

    // "restart": a fresh workload restored from the newest generation
    let gens = checkpoint::list_generations(&cfg.ckpt_dir).unwrap();
    let (step, path) = gens.last().unwrap();
    assert_eq!(*step, 3);
    let mut w2 = FusedWorkload::new(2, 1, true, 2);
    w2.restore_checkpoint(&std::fs::read(path).unwrap()).unwrap();
    let report = Supervisor::new(cfg.clone()).run(&mut w2, STEPS);
    assert!(report.ok());
    log_events(label, &report.events);

    assert_eq!(w2.bits(), reference(2, 1, true, 2, STEPS).bits());
    let _ = std::fs::remove_dir_all(&cfg.ckpt_dir);
}

/// The stream program a *recovered* run re-submits is the same
/// statically race-free program an uninterrupted run records: replay
/// the chaos workload's step shape with tracing on, run the full
/// `exec::verify` happens-before analysis over the trace (via
/// `sim::verify_trace`), and pin that recording + the `LLMQ_VERIFY`
/// scope hook leave the numbers bitwise identical to the supervised
/// reference.
#[test]
fn recovered_step_program_passes_static_verification() {
    let (world, threads, streams) = (2usize, 2usize, 3usize);
    let want = reference(world, threads, true, streams, 1).bits();

    let mut w = FusedWorkload::new(world, threads, true, streams);
    let step = w.step + 1;
    w.ws.ensure(w.world, N);
    w.ws.begin_step();
    w.fill_grads(step);
    let hs = HostStep {
        hp: AdamWParams::default(),
        lr: 3e-4,
        grad_clip: 1.0,
        step,
        counter: w.counter,
        seed: 9,
        n_micro: 2 * world,
        opt_world: OPT_WORLD,
        moments: MomentsMode::Fp32,
    };
    let (ws, p, m, v) = (&mut w.ws, &mut w.p, &mut w.m, &mut w.v);
    let (_norm, trace) = par::with_threads(threads, || {
        exec::with_async(true, || {
            exec::with_verify(true, || {
                exec::with_streams(streams, || {
                    llmq::optim::fused::fused_step_async_traced(ws, p, m, v, &hs)
                })
            })
        })
    });
    llmq::sim::verify_trace(&trace).expect("recovered step program is race-free");
    w.step = step;
    w.counter = w.counter.wrapping_add(3 * N as u32);
    assert_eq!(w.bits(), want, "traced+verified step drifted from reference");
}
