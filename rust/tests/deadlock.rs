//! The §3.2 multi-threaded NCCL deadlock scenario and its CPU-barrier fix,
//! exercised over many random schedules (beyond the unit tests in
//! collectives::barrier).

use std::time::Duration;

use llmq::collectives::{CpuBarrier, DeadlockPolicy, QueueDeadlock};

#[test]
fn deadlock_appears_and_fix_holds_across_sizes() {
    for world in [2usize, 4, 6] {
        // Queue sized so the fast worker alone can exhaust it.
        let post = 2 * world;
        let cap = 1 + 1 + post; // pre + collective + posts of one worker

        // Without the barrier: skewed schedule deadlocks.
        let q = QueueDeadlock::new(world, cap);
        let b = CpuBarrier::new(world);
        let ok = llmq::collectives::run_workers(world, |r| {
            llmq::collectives::iteration(
                r,
                &q,
                &b,
                DeadlockPolicy::None,
                post,
                true,
                Duration::from_millis(300),
            )
        });
        assert!(
            ok.iter().any(|&x| !x),
            "world {world}: expected deadlock without CPU sync"
        );

        // With the paper's CPU-side barrier: always completes.
        let q = QueueDeadlock::new(world, cap);
        let b = CpuBarrier::new(world);
        let ok = llmq::collectives::run_workers(world, |r| {
            llmq::collectives::iteration(
                r,
                &q,
                &b,
                DeadlockPolicy::CpuBarrier,
                post,
                true,
                Duration::from_millis(3000),
            )
        });
        assert!(
            ok.iter().all(|&x| x),
            "world {world}: CPU barrier must prevent the deadlock"
        );
    }
}

#[test]
fn repeated_iterations_with_barrier_stay_live() {
    // Multiple optimizer steps in sequence (the trainer's actual pattern).
    let world = 4;
    let q = QueueDeadlock::new(world, 12);
    let b = CpuBarrier::new(world);
    for _step in 0..5 {
        let ok = llmq::collectives::run_workers(world, |r| {
            llmq::collectives::iteration(
                r,
                &q,
                &b,
                DeadlockPolicy::CpuBarrier,
                8,
                true,
                Duration::from_millis(2000),
            )
        });
        assert!(ok.iter().all(|&x| x));
    }
}
