//! Multi-process chaos tests for the elastic rank runtime (`comm`).
//!
//! These are the NUMERICS.md Rule 6 pins: the multi-process collectives
//! must be bit-identical to the in-process memcpy oracles, and a world
//! that loses a rank mid-step — a real `abort()`ed process, or a
//! partitioned one declared dead by heartbeat timeout — must recover
//! through the coordinator (restore newest restorable generation,
//! respawn or reshard, resume) onto exactly the bits of the
//! uninterrupted run.
//!
//! Every test writes its checkpoints, per-rank logs and coordinator
//! events under `target/multiproc-logs/<test>/` so CI can upload the
//! whole directory on failure.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::Duration;

use llmq::collectives::memcpy::reduce_chunk;
use llmq::collectives::{reduce_scatter_scaled_memcpy, DeviceGroup};
use llmq::comm::wire::FrameKind;
use llmq::comm::workload::DEFAULT_N;
use llmq::comm::{run_coordinator, CoordCfg, Mesh, SyntheticModel};
use llmq::optim::fused::REDUCE_RNG_KEY;
use llmq::precision::CounterRng;
use llmq::train::checkpoint;

fn logdir(test: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/multiproc-logs")
        .join(test);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Load the sharded generation at `step` and require it to be bitwise
/// identical to `want` (the in-process reference run).
fn assert_generation_matches(dir: &Path, step: u32, n: usize, want: &SyntheticModel) {
    let (mut p, mut m, mut v) = (vec![0f32; n], vec![0f32; n], vec![0f32; n]);
    let (got_step, got_counter, _world) =
        checkpoint::load_sharded_into(dir, step, &mut p, &mut m, &mut v).unwrap();
    let (wp, wm, wv, wstep, wcounter) = want.bits();
    assert_eq!(got_step, step);
    assert_eq!(wstep, step, "reference must be run to the compared step");
    assert_eq!(got_counter, wcounter, "SR counter must replay exactly");
    assert_eq!(bits(&p), wp, "params diverged");
    assert_eq!(bits(&m), wm, "first moments diverged");
    assert_eq!(bits(&v), wv, "second moments diverged");
}

// ---------------------------------------------------------------------------
// Collectives parity: real sockets vs the in-process memcpy oracle
// ---------------------------------------------------------------------------

/// Run one distributed reduce-scatter + all-gather over a real TCP mesh
/// (threads standing in for rank processes — the wire path is identical)
/// and pin the gathered flat gradient bitwise to
/// `reduce_scatter_scaled_memcpy`.
fn mesh_matches_oracle(world: usize, n: usize) {
    let seed = 11u32;
    let step = 3u32;
    let counter = 1u32.wrapping_add(3 * n as u32); // as if one step committed
    let scale = 1.0 / (2 * world) as f32;
    let model = SyntheticModel::new(n, seed);

    // Oracle: the in-process reduce over all sources at once.
    let group = DeviceGroup {
        world,
        buffers: (0..world)
            .map(|r| {
                let mut g = vec![0f32; n];
                model.fill_grad(r, step, &mut g);
                g
            })
            .collect(),
    };
    let mut want = vec![0f32; n];
    let rng = CounterRng::new(REDUCE_RNG_KEY ^ seed);
    reduce_scatter_scaled_memcpy(&group, &mut want, scale, &rng, counter);
    let want_bits = bits(&want);

    // Distributed: one thread per rank, full TCP mesh.
    let listeners: Vec<TcpListener> = (0..world)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    let ports: Vec<u16> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().port())
        .collect();
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(r, listener)| {
            let ports = ports.clone();
            let model = model.clone();
            std::thread::spawn(move || -> Vec<u32> {
                let mesh = Mesh::connect(
                    r as u32,
                    world as u32,
                    1,
                    &listener,
                    &ports,
                    Duration::from_secs(20),
                )
                .unwrap();
                let chunk = n / world;
                let own = r * chunk..(r + 1) * chunk;
                let mut local = vec![0f32; n];
                model.fill_grad(r, step, &mut local);
                let mut recv = vec![Vec::new(); world];
                mesh.exchange_grad_slices(step, &local, &mut recv).unwrap();
                let mut flat = vec![0f32; n];
                let srcs: Vec<&[f32]> = (0..world)
                    .map(|q| {
                        if q == r {
                            &local[own.clone()]
                        } else {
                            recv[q].as_slice()
                        }
                    })
                    .collect();
                let rng = CounterRng::new(REDUCE_RNG_KEY ^ seed);
                reduce_chunk(
                    &srcs,
                    0,
                    &mut flat[own.clone()],
                    Some(scale),
                    &rng,
                    counter.wrapping_add(own.start as u32),
                );
                mesh.all_gather_chunks(step, FrameKind::Reduced, &mut flat)
                    .unwrap();
                bits(&flat)
            })
        })
        .collect();
    for (r, h) in handles.into_iter().enumerate() {
        let got = h.join().expect("rank thread panicked");
        assert_eq!(got, want_bits, "rank {r} flat gradient diverged from oracle");
    }
}

#[test]
fn mesh_collectives_match_memcpy_oracle_world2() {
    mesh_matches_oracle(2, DEFAULT_N);
}

#[test]
fn mesh_collectives_match_memcpy_oracle_world4() {
    mesh_matches_oracle(4, DEFAULT_N);
}

#[test]
fn mesh_collectives_match_memcpy_oracle_unaligned_small() {
    // One PIPELINE_BLOCK plus a ragged tail, per-rank chunks unaligned.
    mesh_matches_oracle(2, 8 * 1024 + 4);
    mesh_matches_oracle(4, 8 * 1024 + 4);
}

// ---------------------------------------------------------------------------
// Crash recovery across real process boundaries
// ---------------------------------------------------------------------------

fn base_cfg(dir: &Path) -> CoordCfg {
    CoordCfg {
        exe: PathBuf::from(env!("CARGO_BIN_EXE_llmq")),
        world: 4,
        n: DEFAULT_N,
        seed: 5,
        target_step: 6,
        ckpt_every: 1,
        keep_last: 4,
        ckpt_dir: dir.to_path_buf(),
        max_respawns: 2,
        allow_shrink: true,
        hb_interval_ms: 50,
        hb_timeout_ms: 2000,
        data_timeout_ms: 10_000,
        epoch_timeout_ms: 60_000,
        fault: None,
    }
}

#[test]
fn world4_rank_kill_recovers_bitwise_via_respawn() {
    let dir = logdir("rank-kill-respawn");
    let cfg = CoordCfg {
        fault: Some("rank2:step4:rank-kill".into()),
        ..base_cfg(&dir)
    };
    let (n, seed, target) = (cfg.n, cfg.seed, cfg.target_step);
    let report = run_coordinator(cfg).unwrap();
    assert!(report.ok(), "run failed: {:?}", report.error);
    assert_eq!(report.final_step, target);
    assert_eq!(report.final_world, 4, "respawn must keep the world");
    assert_eq!(report.respawns, 1);
    assert_eq!(report.shrinks, 0);
    assert!(report.epochs >= 2, "the kill must have cost an epoch");

    // Rule 6: recovered ≡ uninterrupted, across the process boundary.
    let want = SyntheticModel::run_reference(n, seed, &[(4, target)]);
    assert_generation_matches(&dir, target, n, &want);

    let events = std::fs::read_to_string(dir.join("coordinator-events.log")).unwrap();
    assert!(events.contains("\"kind\":\"rank-dead\""), "{events}");
    assert!(events.contains("\"rank\":2"), "{events}");
    assert!(events.contains("\"kind\":\"done\""), "{events}");
}

#[test]
fn world4_rank_kill_reshards_to_world3_bitwise() {
    let dir = logdir("rank-kill-shrink");
    let cfg = CoordCfg {
        fault: Some("rank2:step4:rank-kill".into()),
        max_respawns: 0, // no respawn budget: the failure must shed a rank
        ckpt_every: 2,   // generations at steps 2, 4, 6 — restore lands on 2
        ..base_cfg(&dir)
    };
    let (n, seed, target) = (cfg.n, cfg.seed, cfg.target_step);
    let report = run_coordinator(cfg).unwrap();
    assert!(report.ok(), "run failed: {:?}", report.error);
    assert_eq!(report.final_step, target);
    assert_eq!(report.final_world, 3, "W→W−1 reshard");
    assert_eq!(report.respawns, 0);
    assert_eq!(report.shrinks, 1);

    // The kill fires entering step 4, so steps 1–3 ran at world 4 and
    // only the step-2 generation is durable: the resharded run replays
    // steps 3–6 at world 3. Rule 6 again: identical to an in-process
    // run with the same W→W−1 schedule.
    let want = SyntheticModel::run_reference(n, seed, &[(4, 2), (3, target)]);
    assert_generation_matches(&dir, target, n, &want);

    let events = std::fs::read_to_string(dir.join("coordinator-events.log")).unwrap();
    assert!(events.contains("\"kind\":\"shrink\""), "{events}");
    assert!(events.contains("\"restore\":2"), "{events}");
}

#[test]
fn world4_partition_is_declared_dead_and_recovers_bitwise() {
    let dir = logdir("partition");
    let cfg = CoordCfg {
        // Drop 20 consecutive beats at 25ms spacing: a 500ms silence
        // against a 250ms timeout — decisively dead, 10× the normal
        // inter-beat gap so a healthy rank can't trip it.
        fault: Some("rank1:step3:partition:beats20".into()),
        hb_interval_ms: 25,
        hb_timeout_ms: 250,
        max_respawns: 1,
        ..base_cfg(&dir)
    };
    let (n, seed, target) = (cfg.n, cfg.seed, cfg.target_step);
    let report = run_coordinator(cfg).unwrap();
    assert!(report.ok(), "run failed: {:?}", report.error);
    assert_eq!(report.final_step, target);
    assert_eq!(report.final_world, 4);
    assert_eq!(report.respawns, 1, "partition must cost exactly one epoch");

    // The partitioned process was *alive* — only silent. It must still
    // have been declared dead, killed, and the run must land on the
    // uninterrupted bits no matter which generation the restore used.
    let want = SyntheticModel::run_reference(n, seed, &[(4, target)]);
    assert_generation_matches(&dir, target, n, &want);

    let events = std::fs::read_to_string(dir.join("coordinator-events.log")).unwrap();
    assert!(events.contains("missed heartbeats"), "{events}");
}

#[test]
fn distributed_cli_smoke_matches_reference() {
    let dir = logdir("cli-smoke");
    let (n, seed, target) = (DEFAULT_N, 9u32, 3u32);
    let status = Command::new(env!("CARGO_BIN_EXE_llmq"))
        .args([
            "train",
            "--distributed",
            "2",
            "--steps",
            "3",
            "--dist-n",
            &n.to_string(),
            "--seed",
            &seed.to_string(),
            "--ckpt-dir",
            dir.to_str().unwrap(),
            "--hb-timeout-ms",
            "4000",
        ])
        .env_remove("LLMQ_FAULT")
        .status()
        .unwrap();
    assert!(status.success(), "CLI run failed: {status}");
    let want = SyntheticModel::run_reference(n, seed, &[(2, target)]);
    assert_generation_matches(&dir, target, n, &want);
}
