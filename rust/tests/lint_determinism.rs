//! Crate-wide determinism lint, driven from the test harness.
//!
//! The lint itself lives in `tools/lint_determinism.rs` (repo root) and
//! is included here via `#[path]`, so `cargo test` runs it with no extra
//! binary, build step, or dependency. The headline test walks
//! `rust/src/` and fails — listing every violation with file, line and
//! rule — if any source file regresses on the determinism rules:
//! hash-collection iteration, wall-clock/OS-entropy randomness, unkeyed
//! stochastic rounding, or `unsafe` outside `precision::backend`. The
//! remaining tests pin the lint's own behaviour on synthetic sources so
//! a rule cannot silently rot.

#[path = "../../tools/lint_determinism.rs"]
mod lint_determinism;

use std::path::Path;

use lint_determinism as lint;

/// The headline check: every file under `rust/src/` passes the lint
/// (modulo the per-file `HASH_ALLOWLIST`, each entry of which carries a
/// written reason).
#[test]
fn crate_sources_pass_determinism_lint() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = lint::lint_tree(&src).expect("walk rust/src");
    assert!(findings.is_empty(), "{}", lint::render(&findings));
}

#[test]
fn flags_hash_collections_and_respects_allowlist() {
    let source = "use std::collections::HashMap;\nfn f() {}\n";
    let findings = lint::lint_file(Path::new("src/exec/new_module.rs"), source);
    assert_eq!(findings.len(), 1, "{}", lint::render(&findings));
    assert_eq!(findings[0].rule, lint::R1_HASH_COLLECTIONS);
    assert_eq!(findings[0].line, 1);

    // The same source under an allowlisted path is accepted.
    let ok = lint::lint_file(Path::new("rust/src/util/args.rs"), source);
    assert!(ok.is_empty(), "{}", lint::render(&ok));
    // ...and every allowlist entry carries a reason.
    for (file, why) in lint::HASH_ALLOWLIST {
        assert!(!why.is_empty(), "allowlist entry {file} has no reason");
    }
}

#[test]
fn flags_wallclock_and_entropy_randomness() {
    for bad in [
        "fn now() { let _t = std::time::SystemTime::now(); }\n",
        "fn seed() { let mut r = thread_rng(); }\n",
        "fn seed() { let r = SmallRng::from_entropy(); }\n",
        "fn draw() -> f32 { rand::random() }\n",
    ] {
        let findings = lint::lint_file(Path::new("src/x.rs"), bad);
        assert_eq!(findings.len(), 1, "source: {bad}\n{}", lint::render(&findings));
        assert_eq!(findings[0].rule, lint::R2_WALLCLOCK_RANDOMNESS);
    }
}

#[test]
fn flags_instant_outside_telemetry_and_respects_clock_allowlist() {
    let bad = "use std::time::Instant;\nfn t() { let _x = Instant::now(); }\n";
    let findings = lint::lint_file(Path::new("src/exec/mod.rs"), bad);
    assert_eq!(findings.len(), 2, "{}", lint::render(&findings));
    assert!(findings.iter().all(|f| f.rule == lint::R2_WALLCLOCK_RANDOMNESS));
    assert_eq!(findings[0].line, 1);
    assert_eq!(findings[1].line, 2);

    // The telemetry module is the one sanctioned clock reader.
    let ok = lint::lint_file(Path::new("rust/src/telemetry/mod.rs"), bad);
    assert!(ok.is_empty(), "{}", lint::render(&ok));
    // Exactly one clock allowlist entry, with a written reason — the
    // issue's contract: the telemetry clock, nothing else.
    assert_eq!(lint::CLOCK_ALLOWLIST.len(), 1);
    assert_eq!(lint::CLOCK_ALLOWLIST[0].0, "telemetry/mod.rs");
    assert!(!lint::CLOCK_ALLOWLIST[0].1.is_empty());
    // `Instant` in a comment stays fine (strings/comments stripped).
    let commented = "// the caller feeds Instant-derived ms\nfn f() {}\n";
    let ok = lint::lint_file(Path::new("src/comm/liveness.rs"), commented);
    assert!(ok.is_empty(), "{}", lint::render(&ok));
}

#[test]
fn flags_unkeyed_stochastic_rounding() {
    // No counter key in the parameter list: rejected.
    let bad = "pub fn stochastic_round_q(x: f32, p: f32) -> f32 { x + p }\n";
    let findings = lint::lint_file(Path::new("src/x.rs"), bad);
    assert_eq!(findings.len(), 1, "{}", lint::render(&findings));
    assert_eq!(findings[0].rule, lint::R3_UNKEYED_SR);
    assert_eq!(findings[0].line, 1);

    // Keyed (counter / ctr / rng_draw), multi-line signatures, and
    // zero-argument test helpers are all accepted.
    for ok in [
        "pub fn stochastic_round_q(x: f32, counter: u32) -> f32 { x }\n",
        "fn sr_fold(t: f32, ctr: u32) -> f32 { t }\n",
        "pub fn round_fp8_sr(fmt: u8, x: f32, rng_draw: u32) -> f32 { x }\n",
        "pub fn mx_encode_sr(\n    x: &[f32],\n    counter_base: u32,\n) {}\n",
        "fn sr_parity_with_python() {}\n",
    ] {
        let findings = lint::lint_file(Path::new("src/x.rs"), ok);
        assert!(findings.is_empty(), "source: {ok}\n{}", lint::render(&findings));
    }
}

#[test]
fn flags_unsafe_outside_backend_only() {
    let source = "fn f(p: *const u32) -> u32 { unsafe { *p } }\n";
    let findings = lint::lint_file(Path::new("src/exec/mod.rs"), source);
    assert_eq!(findings.len(), 1, "{}", lint::render(&findings));
    assert_eq!(findings[0].rule, lint::R4_UNSAFE_OUTSIDE_BACKEND);

    let ok = lint::lint_file(Path::new("src/precision/backend/x86.rs"), source);
    assert!(ok.is_empty(), "{}", lint::render(&ok));
}

#[test]
fn comments_and_strings_do_not_trip_rules() {
    let source = "\
// a comment naming HashMap and thread_rng is fine
/* block comments too: HashSet, SystemTime,
   even /* nested */ ones mentioning unsafe */
fn f() -> &'static str {
    \"string literals naming HashMap or unsafe are data\"
}
fn g() -> &'static str {
    r#\"raw strings with HashSet and \"quotes\" inside\"#
}
";
    let findings = lint::lint_file(Path::new("src/x.rs"), source);
    assert!(findings.is_empty(), "{}", lint::render(&findings));
    // Stripping preserves line structure, so finding line numbers are real.
    let stripped = lint::strip_comments_and_strings(source);
    assert_eq!(stripped.lines().count(), source.lines().count());
}

/// The tree walker visits files recursively and reports findings by
/// path and line.
#[test]
fn tree_walk_finds_violations_recursively() {
    let root = Path::new(env!("CARGO_TARGET_TMPDIR")).join("lint_determinism_walk");
    let nested = root.join("src").join("deep");
    std::fs::create_dir_all(&nested).unwrap();
    std::fs::write(root.join("src").join("ok.rs"), "fn f() {}\n").unwrap();
    std::fs::write(
        nested.join("bad.rs"),
        "fn f() {}\nuse std::collections::HashSet;\n",
    )
    .unwrap();
    let findings = lint::lint_tree(&root.join("src")).expect("walk fixture tree");
    assert_eq!(findings.len(), 1, "{}", lint::render(&findings));
    assert_eq!(findings[0].rule, lint::R1_HASH_COLLECTIONS);
    assert_eq!(findings[0].line, 2);
    assert!(
        findings[0].file.to_string_lossy().ends_with("bad.rs"),
        "{}",
        findings[0]
    );
}
