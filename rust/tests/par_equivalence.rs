//! Parallelism-correctness suite for the parallel execution layer
//! (`util::par`) and the SIMD tier beneath it (`precision::backend`):
//! every parallel hot path must produce results bit-identical to its
//! single-threaded scalar reference at 1, 2 and 8 worker threads —
//! including empty, lane-remainder and non-chunk-aligned lengths — and
//! every vector kernel must match the scalar spec bitwise whatever
//! backend `LLMQ_SIMD`/detection resolves (the arch-direct tests at the
//! bottom pin the AVX2/NEON kernels even when dispatch is scalar) —
//! including the vector AdamW update (pinned against an independent
//! re-derivation of the update math + SR counter layout, at denormal/
//! NaN grads and eps extremes) and the widened per-lane f64 norm grid
//! (NUMERICS.md Rule 2a). The one documented exception is
//! `global_norm`, whose fixed-grid tree reduction is bit-identical
//! *across thread counts and backends* but only ULP-bounded against
//! the unchunked serial fold.

use llmq::collectives::{DeviceGroup, memcpy::reduce_scatter_memcpy_serial, reduce_scatter_memcpy};
use llmq::optim::{AdamW, AdamWParams, clip_global_norm, global_norm, global_norm_serial};
use llmq::precision::{bf16, CounterRng, E4M3, E5M2, fp8};
use llmq::util::par;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Test lengths: empty, single, sub-grain, non-aligned multi-chunk.
const LENS: [usize; 5] = [0, 1, 1023, 65_537, 100_003];

/// Lane-remainder sweep for the SIMD kernels: 0, 1, lane−1, lane, lane+1
/// for both lane widths (NEON 4, AVX2 8), a couple of odd multi-vector
/// sizes, and non-`REDUCE_CHUNK`-aligned lengths (`REDUCE_CHUNK` is
/// 65 536, `SIMD_ALIGN` is 16 — 65 537 and 100 003 straddle both).
const SIMD_LENS: [usize; 13] = [0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 65_537, 100_003];

fn data(n: usize, salt: u32) -> Vec<f32> {
    let rng = CounterRng::new(salt);
    (0..n)
        .map(|i| (rng.next_f32(i as u32) - 0.5) * 16.0)
        .collect()
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn fp8_quantize_parallel_equivalence() {
    for fmt in [E4M3, E5M2] {
        for n in LENS {
            let base = data(n, 0xF8);
            let mut reference = base.clone();
            let s_ref = fmt.quantize_serial(&mut reference);
            for t in THREAD_COUNTS {
                let mut x = base.clone();
                let s = par::with_threads(t, || fmt.quantize(&mut x));
                assert_eq!(s.to_bits(), s_ref.to_bits(), "{} n={n} t={t}", fmt.name);
                assert_eq!(bits(&x), bits(&reference), "{} n={n} t={t}", fmt.name);
            }
        }
    }
}

#[test]
fn fp8_codec_roundtrip_parallel_equivalence() {
    for n in LENS {
        let base = data(n, 0xC0DE);
        let (b_ref, s_ref) = fp8::encode_tensor_serial(E4M3, &base);
        let mut d_ref = vec![0f32; n];
        fp8::decode_tensor_serial(E4M3, &b_ref, s_ref, &mut d_ref);
        for t in THREAD_COUNTS {
            let (bytes, scale) = par::with_threads(t, || fp8::encode_tensor(E4M3, &base));
            assert_eq!(bytes, b_ref, "encode n={n} t={t}");
            assert_eq!(scale.to_bits(), s_ref.to_bits());
            let mut dec = vec![0f32; n];
            par::with_threads(t, || fp8::decode_tensor(E4M3, &bytes, scale, &mut dec));
            assert_eq!(bits(&dec), bits(&d_ref), "decode n={n} t={t}");
        }
    }
}

#[test]
fn bf16_stochastic_round_parallel_equivalence() {
    let rng = CounterRng::new(0x11A17);
    for n in LENS {
        let base = data(n, 0xB16);
        for counter_base in [0u32, 977, u32::MAX - 5] {
            let mut reference = base.clone();
            bf16::stochastic_round_slice_serial(&mut reference, &rng, counter_base);
            for t in THREAD_COUNTS {
                let mut x = base.clone();
                par::with_threads(t, || bf16::stochastic_round_slice(&mut x, &rng, counter_base));
                assert_eq!(bits(&x), bits(&reference), "n={n} t={t} cb={counter_base}");
            }
        }
    }
}

#[test]
fn bf16_accumulate_parallel_equivalence() {
    for n in LENS {
        let base = data(n, 0xACC);
        let add = data(n, 0xADD);
        let mut reference = base.clone();
        bf16::accumulate_bf16_serial(&mut reference, &add);
        for t in THREAD_COUNTS {
            let mut acc = base.clone();
            par::with_threads(t, || bf16::accumulate_bf16(&mut acc, &add));
            assert_eq!(bits(&acc), bits(&reference), "n={n} t={t}");
        }
    }
}

#[test]
fn bf16_pack_unpack_parallel_equivalence() {
    for n in LENS {
        let mut base = data(n, 0xBA9);
        bf16::round_slice(&mut base);
        let mut packed_ref = vec![0u16; n];
        let mut packed = vec![0u16; n];
        // serial loop reference
        for (o, &v) in packed_ref.iter_mut().zip(&base) {
            *o = (v.to_bits() >> 16) as u16;
        }
        for t in THREAD_COUNTS {
            par::with_threads(t, || bf16::pack(&base, &mut packed));
            assert_eq!(packed, packed_ref, "pack n={n} t={t}");
            let mut un = vec![0f32; n];
            par::with_threads(t, || bf16::unpack(&packed, &mut un));
            assert_eq!(bits(&un), bits(&base), "unpack n={n} t={t}");
        }
    }
}

#[test]
fn adamw_step_parallel_equivalence() {
    let opt = AdamW::new(AdamWParams::default());
    for n in LENS {
        let p0 = data(n, 0x9A);
        let m0 = data(n, 0x9B);
        let v0: Vec<f32> = data(n, 0x9C).iter().map(|x| x.abs()).collect();
        let g = data(n, 0x9D);
        let run_serial = || {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            opt.step_serial(&mut p, &mut m, &mut v, &g, 1e-3, 7, 4321, n as u32 + 13);
            (p, m, v)
        };
        let (pr, mr, vr) = run_serial();
        for t in THREAD_COUNTS {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            par::with_threads(t, || {
                opt.step(&mut p, &mut m, &mut v, &g, 1e-3, 7, 4321, n as u32 + 13)
            });
            assert_eq!(bits(&p), bits(&pr), "p n={n} t={t}");
            assert_eq!(bits(&m), bits(&mr), "m n={n} t={t}");
            assert_eq!(bits(&v), bits(&vr), "v n={n} t={t}");
        }
    }
}

#[test]
fn global_norm_identical_across_threads_and_ulp_close_to_serial() {
    for n in LENS {
        let g = data(n, 0x6068);
        let one = par::with_threads(1, || global_norm(&g));
        for t in THREAD_COUNTS {
            let norm = par::with_threads(t, || global_norm(&g));
            // fixed reduction grid → bit-identical for every thread count
            assert_eq!(norm.to_bits(), one.to_bits(), "n={n} t={t}");
        }
        let serial = global_norm_serial(&g);
        let tol = serial.abs() * 1e-6f32 + 1e-12f32;
        assert!(
            (one - serial).abs() <= tol,
            "n={n}: chunked {one} vs serial {serial}"
        );
    }
}

#[test]
fn clip_global_norm_parallel_equivalence() {
    let n = 100_003;
    let base = data(n, 0xC11F);
    let mut reference = base.clone();
    let pre_ref = {
        // reference: serial norm + serial scale
        let norm = par::with_threads(1, || global_norm(&reference));
        let max_norm = norm / 3.0;
        let s = max_norm / norm;
        for v in reference.iter_mut() {
            *v *= s;
        }
        (norm, max_norm)
    };
    for t in THREAD_COUNTS {
        let mut g = base.clone();
        let pre = par::with_threads(t, || clip_global_norm(&mut g, pre_ref.1));
        assert_eq!(pre.to_bits(), pre_ref.0.to_bits(), "pre-clip norm t={t}");
        assert_eq!(bits(&g), bits(&reference), "clipped grads t={t}");
    }
}

#[test]
fn reduce_scatter_parallel_equivalence() {
    // chunk sizes straddle the pipeline block (8192): unaligned + aligned
    for (world, chunk) in [(2usize, 5usize), (4, 1000), (2, 8192), (4, 20_011)] {
        let n = world * chunk;
        let rng = CounterRng::new(0x5CA7);
        let grads = DeviceGroup::from_fn(world, n, |r, i| {
            bf16::round_to_bf16((rng.next_f32((r * n + i) as u32) - 0.5) * 2.0)
        });
        let mk_acc = || -> Vec<Vec<f32>> {
            (0..world)
                .map(|w| {
                    (0..chunk)
                        .map(|i| bf16::round_to_bf16(rng.next_f32((w * chunk + i) as u32 ^ 0xACC)))
                        .collect()
                })
                .collect()
        };
        let mut reference = mk_acc();
        reduce_scatter_memcpy_serial(&grads, &mut reference, &CounterRng::new(3), 991);
        for t in THREAD_COUNTS {
            let mut acc = mk_acc();
            par::with_threads(t, || {
                reduce_scatter_memcpy(&grads, &mut acc, &CounterRng::new(3), 991)
            });
            for w in 0..world {
                assert_eq!(
                    bits(&acc[w]),
                    bits(&reference[w]),
                    "world={world} chunk={chunk} w={w} t={t}"
                );
            }
        }
    }
}

#[test]
fn all_gather_parallel_matches_any_thread_count() {
    for (world, chunk) in [(2usize, 7usize), (4, 3000), (6, 9001)] {
        let shards: Vec<Vec<f32>> = (0..world)
            .map(|r| (0..chunk).map(|i| (r * 100_000 + i) as f32).collect())
            .collect();
        let mut reference = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
        par::with_threads(1, || llmq::collectives::all_gather_memcpy(&shards, &mut reference));
        for t in THREAD_COUNTS {
            let mut out = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
            par::with_threads(t, || llmq::collectives::all_gather_memcpy(&shards, &mut out));
            assert_eq!(out.buffers, reference.buffers, "world={world} t={t}");
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD tier (precision::backend): dispatch-level and arch-direct kernels
// must match the scalar spec bitwise at every lane remainder, including
// IEEE special values (NaN, ±0, ±inf, subnormals, saturating magnitudes).
// ---------------------------------------------------------------------------

use llmq::precision::backend::MomentsMode;
use llmq::precision::fp8::stochastic_round_fp8;
use llmq::precision::{absmax_serial, backend, round_to_bf16, stochastic_round_bf16, Fp8Format};

/// Random data with IEEE special values planted in the leading slots
/// (when the length allows) so every kernel's NaN/zero/saturation blends
/// are exercised at every lane remainder.
fn simd_data(n: usize, salt: u32) -> Vec<f32> {
    let mut x = data(n, salt);
    let specials = [
        f32::NAN,
        -f32::NAN,
        0.0,
        -0.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
        1e-40,
        -1e-40,
        448.0,
        -448.0,
        57_344.0,
        -1e9,
        1e9,
    ];
    for (slot, &s) in x.iter_mut().zip(specials.iter()) {
        *slot = s;
    }
    x
}

/// One backend implementation under test (the safe dispatch layer, or an
/// arch kernel set behind thin wrappers).
struct BackendFns {
    label: &'static str,
    absmax: fn(&[f32]) -> f32,
    fp8_round_scaled: fn(Fp8Format, &mut [f32], f32),
    fp8_encode_scaled: fn(Fp8Format, &[f32], f32, &mut [u8]),
    fp8_decode_scaled: fn(Fp8Format, &[u8], f32, &mut [f32]),
    bf16_round: fn(&mut [f32]),
    bf16_stochastic_round: fn(&mut [f32], &CounterRng, u32),
    bf16_scaled_round: fn(&[f32], &mut [f32], f32),
    bf16_accumulate: fn(&mut [f32], &[f32]),
    bf16_pack: fn(&[f32], &mut [u16]),
    bf16_unpack: fn(&[u16], &mut [f32]),
    sr_reduce_block: fn(&[&[f32]], usize, &mut [f32], Option<f32>, &CounterRng, u32),
    sumsq_lanes_into: fn(&[f32], &mut [f64]),
    adamw_update: fn(&backend::AdamWSpec, &mut [f32], &mut [f32], &mut [f32], &[f32], u32),
}

/// Independent re-derivation of the Rule 2a widened-lane sum of squares
/// (NUMERICS.md): element `r` contributes its f64 square to lane
/// `r % NORM_LANES`, ascending `r` within each lane.
fn sumsq_lanes_spec(x: &[f32]) -> [f64; backend::NORM_LANES] {
    let mut lanes = [0.0f64; backend::NORM_LANES];
    for (r, &v) in x.iter().enumerate() {
        lanes[r % backend::NORM_LANES] += (v as f64) * (v as f64);
    }
    lanes
}

/// Independent re-derivation of the fused clip + AdamW + SR element
/// loop from the paper's formulas — the oracle the vector AdamW kernels
/// (and the dispatch layer) are pinned against. Deliberately *not* a
/// call into the crate's kernel, so a transcription bug in the shared
/// scalar loop cannot hide.
fn adamw_update_spec(
    spec: &backend::AdamWSpec,
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    counter_base: u32,
) {
    let hp = &spec.hp;
    for i in 0..p.len() {
        let gi = match spec.clip_scale {
            Some(s) => round_to_bf16(g[i] * s),
            None => g[i],
        };
        let m2 = hp.beta1 * m[i] + (1.0 - hp.beta1) * gi;
        let v2 = hp.beta2 * v[i] + (1.0 - hp.beta2) * gi * gi;
        let upd = (m2 / spec.bc1) / ((v2 / spec.bc2).sqrt() + hp.eps) + hp.weight_decay * p[i];
        let p2 = p[i] - spec.lr * upd;
        let c = counter_base.wrapping_add(i as u32);
        p[i] = stochastic_round_bf16(p2, &spec.rng_p, c);
        m[i] = match spec.moments {
            MomentsMode::Fp32 => stochastic_round_bf16(m2, &spec.rng_m, c.wrapping_add(spec.shard)),
            MomentsMode::Fp8 => stochastic_round_fp8(
                E5M2,
                m2,
                spec.rng_m.next_u32(c.wrapping_add(spec.shard)),
            ),
        };
        v[i] = stochastic_round_bf16(v2, &spec.rng_v, c.wrapping_add(spec.shard.wrapping_mul(2)));
    }
}

/// The AdamW-update battery: lane-remainder lengths, denormal/NaN/inf
/// grads and params, eps extremes (0, tiny, huge), clip on/off, counter
/// bases straddling the u32 wrap — every combination pinned bitwise to
/// the independent scalar spec above.
fn check_adamw_matches_spec(b: &BackendFns) {
    let lb = b.label;
    let hps = [
        (0.9f32, 0.95f32, 1e-8f32, 0.1f32),
        (0.9, 0.999, 0.0, 0.0),    // eps = 0: div by bare sqrt
        (0.5, 0.5, 1e30, 0.01),    // huge eps: denominator dominated
    ];
    for n in SIMD_LENS {
        let p0 = simd_data(n, 0xAD01); // NaN/±0/±inf/denormals planted
        let m0 = data(n, 0xAD02);
        let v0: Vec<f32> = simd_data(n, 0xAD03).iter().map(|x| x.abs()).collect();
        let g = simd_data(n, 0xAD04); // denormal/NaN grads
        for &(beta1, beta2, eps, weight_decay) in &hps {
            for clip_scale in [None, Some(0.37f32)] {
                for moments in [MomentsMode::Fp32, MomentsMode::Fp8] {
                    for counter_base in [0u32, u32::MAX - 7] {
                        let spec = backend::AdamWSpec {
                            hp: AdamWParams {
                                beta1,
                                beta2,
                                eps,
                                weight_decay,
                            },
                            lr: 3e-4,
                            bc1: 1.0 - beta1 * beta1,
                            bc2: 1.0 - beta2 * beta2,
                            clip_scale,
                            moments,
                            rng_p: CounterRng::new(0x11A17),
                            rng_m: CounterRng::new(0xA110),
                            rng_v: CounterRng::new(0xB220),
                            shard: n as u32 + 13,
                        };
                        let (mut pw, mut mw, mut vw) = (p0.clone(), m0.clone(), v0.clone());
                        adamw_update_spec(&spec, &mut pw, &mut mw, &mut vw, &g, counter_base);
                        let (mut pg, mut mg, mut vg) = (p0.clone(), m0.clone(), v0.clone());
                        (b.adamw_update)(&spec, &mut pg, &mut mg, &mut vg, &g, counter_base);
                        let ctx = format!(
                            "{lb} adamw n={n} eps={eps} clip={clip_scale:?} \
                             moments={moments:?} cb={counter_base}"
                        );
                        assert_eq!(bits(&pg), bits(&pw), "p {ctx}");
                        assert_eq!(bits(&mg), bits(&mw), "m {ctx}");
                        assert_eq!(bits(&vg), bits(&vw), "v {ctx}");
                    }
                }
            }
        }
    }
}

/// Pin every kernel of `b` bit-identical to the scalar spec across the
/// `SIMD_LENS` lane-remainder sweep.
fn check_backend_matches_scalar_spec(b: &BackendFns) {
    let rng = CounterRng::new(0x11A17);
    let lb = b.label;
    for n in SIMD_LENS {
        let base = simd_data(n, 0x51);

        assert_eq!(
            (b.absmax)(&base).to_bits(),
            absmax_serial(&base).to_bits(),
            "{lb} absmax n={n}"
        );

        for fmt in [E4M3, E5M2] {
            for scale in [1.0f32, 0.37] {
                let mut want = base.clone();
                for v in want.iter_mut() {
                    *v = fmt.round(*v / scale);
                }
                let mut got = base.clone();
                (b.fp8_round_scaled)(fmt, &mut got, scale);
                assert_eq!(bits(&got), bits(&want), "{lb} {} round n={n} s={scale}", fmt.name);

                let want_b: Vec<u8> =
                    base.iter().map(|&v| fmt.encode(fmt.round(v / scale))).collect();
                let mut got_b = vec![0u8; n];
                (b.fp8_encode_scaled)(fmt, &base, scale, &mut got_b);
                assert_eq!(got_b, want_b, "{lb} {} encode n={n} s={scale}", fmt.name);

                let mut want_d = vec![0f32; n];
                for (o, &byte) in want_d.iter_mut().zip(&want_b) {
                    *o = fmt.decode(byte) * scale;
                }
                let mut got_d = vec![0f32; n];
                (b.fp8_decode_scaled)(fmt, &want_b, scale, &mut got_d);
                assert_eq!(bits(&got_d), bits(&want_d), "{lb} {} decode n={n} s={scale}", fmt.name);
            }
        }

        let mut want = base.clone();
        bf16::round_slice_serial(&mut want);
        let mut got = base.clone();
        (b.bf16_round)(&mut got);
        assert_eq!(bits(&got), bits(&want), "{lb} bf16 rne n={n}");

        // counter bases straddling the u32 wrap
        for cb in [0u32, 977, u32::MAX - 5] {
            let mut want = base.clone();
            bf16::stochastic_round_slice_serial(&mut want, &rng, cb);
            let mut got = base.clone();
            (b.bf16_stochastic_round)(&mut got, &rng, cb);
            assert_eq!(bits(&got), bits(&want), "{lb} bf16 sr n={n} cb={cb}");
        }

        let mut want = vec![0f32; n];
        bf16::scaled_round_into_serial(&base, &mut want, 0.25);
        let mut got = vec![0f32; n];
        (b.bf16_scaled_round)(&base, &mut got, 0.25);
        assert_eq!(bits(&got), bits(&want), "{lb} bf16 scaled n={n}");

        let add = data(n, 0xADD);
        let mut want = base.clone();
        bf16::accumulate_bf16_serial(&mut want, &add);
        let mut got = base.clone();
        (b.bf16_accumulate)(&mut got, &add);
        assert_eq!(bits(&got), bits(&want), "{lb} bf16 acc n={n}");

        let mut grid = base.clone();
        bf16::round_slice_serial(&mut grid);
        let want_p: Vec<u16> = grid.iter().map(|v| (v.to_bits() >> 16) as u16).collect();
        let mut got_p = vec![0u16; n];
        (b.bf16_pack)(&grid, &mut got_p);
        assert_eq!(got_p, want_p, "{lb} pack n={n}");
        let want_u: Vec<f32> = want_p
            .iter()
            .map(|&w| f32::from_bits((w as u32) << 16))
            .collect();
        let mut got_u = vec![0f32; n];
        (b.bf16_unpack)(&want_p, &mut got_u);
        assert_eq!(bits(&got_u), bits(&want_u), "{lb} unpack n={n}");

        // widened-lane norm grid: per-lane f64 sums pinned bitwise
        let want_lanes = sumsq_lanes_spec(&base);
        let mut got_lanes = [0.0f64; backend::NORM_LANES];
        (b.sumsq_lanes_into)(&base, &mut got_lanes);
        for l in 0..backend::NORM_LANES {
            assert_eq!(
                got_lanes[l].to_bits(),
                want_lanes[l].to_bits(),
                "{lb} sumsq lane {l} n={n}"
            );
        }
        assert_eq!(
            backend::fold_lanes(&got_lanes).to_bits(),
            backend::fold_lanes(&want_lanes).to_bits(),
            "{lb} sumsq fold n={n}"
        );

        // SR reduce epilogue: world sizes, block offsets, scaled/unscaled
        for world in [1usize, 2, 4] {
            let srcs: Vec<Vec<f32>> = (0..world)
                .map(|w| simd_data(n + 32, 0x70 + w as u32))
                .collect();
            for blk_base in [0usize, 5, 16] {
                for scale in [None, Some(1.0f32 / 3.0)] {
                    let acc0 = data(n, 0xACC);
                    let mut want = acc0.clone();
                    for (j, a) in want.iter_mut().enumerate() {
                        let mut sum = *a;
                        for s in &srcs {
                            let g = s[blk_base + j];
                            sum += match scale {
                                Some(sc) => round_to_bf16(g * sc),
                                None => g,
                            };
                        }
                        *a = stochastic_round_bf16(
                            sum,
                            &rng,
                            991u32.wrapping_add((blk_base + j) as u32),
                        );
                    }
                    let mut got = acc0.clone();
                    let src_refs: Vec<&[f32]> = srcs.iter().map(|s| s.as_slice()).collect();
                    (b.sr_reduce_block)(&src_refs, blk_base, &mut got, scale, &rng, 991);
                    assert_eq!(
                        bits(&got),
                        bits(&want),
                        "{lb} sr_reduce world={world} n={n} base={blk_base} scale={scale:?}"
                    );
                }
            }
        }
    }
}

/// Whatever backend `LLMQ_SIMD`/detection resolves for this process must
/// match the scalar spec (trivially true when it resolves to scalar —
/// CI runs the suite both ways).
#[test]
fn backend_dispatch_matches_scalar_spec_at_lane_remainders() {
    let fns = BackendFns {
        label: "dispatch",
        absmax: backend::absmax,
        fp8_round_scaled: backend::fp8_round_scaled,
        fp8_encode_scaled: backend::fp8_encode_scaled,
        fp8_decode_scaled: backend::fp8_decode_scaled,
        bf16_round: backend::bf16_round,
        bf16_stochastic_round: backend::bf16_stochastic_round,
        bf16_scaled_round: backend::bf16_scaled_round,
        bf16_accumulate: backend::bf16_accumulate,
        bf16_pack: backend::bf16_pack,
        bf16_unpack: backend::bf16_unpack,
        sr_reduce_block: backend::sr_reduce_block,
        sumsq_lanes_into: backend::sumsq_lanes_into,
        adamw_update: backend::adamw_update,
    };
    check_backend_matches_scalar_spec(&fns);
    check_adamw_matches_spec(&fns);
}

/// Thin safe wrappers over the AVX2 kernels — sound only after the
/// feature gate in the test below has confirmed AVX2.
#[cfg(target_arch = "x86_64")]
mod avx2_wrap {
    use llmq::precision::backend::x86;
    use llmq::precision::{CounterRng, Fp8Format};

    pub fn absmax(x: &[f32]) -> f32 {
        unsafe { x86::absmax(x) }
    }
    pub fn fp8_round_scaled(f: Fp8Format, x: &mut [f32], s: f32) {
        unsafe { x86::fp8_round_scaled(f, x, s) }
    }
    pub fn fp8_encode_scaled(f: Fp8Format, x: &[f32], s: f32, o: &mut [u8]) {
        unsafe { x86::fp8_encode_scaled(f, x, s, o) }
    }
    pub fn fp8_decode_scaled(f: Fp8Format, b: &[u8], s: f32, o: &mut [f32]) {
        unsafe { x86::fp8_decode_scaled(f, b, s, o) }
    }
    pub fn bf16_round(x: &mut [f32]) {
        unsafe { x86::bf16_round(x) }
    }
    pub fn bf16_stochastic_round(x: &mut [f32], r: &CounterRng, c: u32) {
        unsafe { x86::bf16_stochastic_round(x, r, c) }
    }
    pub fn bf16_scaled_round(x: &[f32], o: &mut [f32], s: f32) {
        unsafe { x86::bf16_scaled_round(x, o, s) }
    }
    pub fn bf16_accumulate(a: &mut [f32], x: &[f32]) {
        unsafe { x86::bf16_accumulate(a, x) }
    }
    pub fn bf16_pack(x: &[f32], o: &mut [u16]) {
        unsafe { x86::bf16_pack(x, o) }
    }
    pub fn bf16_unpack(b: &[u16], o: &mut [f32]) {
        unsafe { x86::bf16_unpack(b, o) }
    }
    pub fn sr_reduce_block(
        s: &[&[f32]],
        base: usize,
        blk: &mut [f32],
        sc: Option<f32>,
        r: &CounterRng,
        c: u32,
    ) {
        unsafe { x86::sr_reduce_block(s, base, blk, sc, r, c) }
    }
    pub fn sumsq_lanes_into(x: &[f32], lanes: &mut [f64]) {
        unsafe { x86::sumsq_lanes_into(x, lanes) }
    }
    pub fn adamw_update(
        spec: &llmq::precision::backend::AdamWSpec,
        p: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        c: u32,
    ) {
        unsafe { x86::adamw_update(spec, p, m, v, g, c) }
    }
}

/// The AVX2 kernels themselves (not just whatever dispatch picked) are
/// pinned to the scalar spec — this runs even under `LLMQ_SIMD=scalar`.
#[cfg(target_arch = "x86_64")]
#[test]
fn avx2_kernels_bit_identical_to_scalar_spec() {
    if !std::arch::is_x86_feature_detected!("avx2") {
        eprintln!("skipping avx2 kernel pin: host CPU has no AVX2");
        return;
    }
    let fns = BackendFns {
        label: "avx2",
        absmax: avx2_wrap::absmax,
        fp8_round_scaled: avx2_wrap::fp8_round_scaled,
        fp8_encode_scaled: avx2_wrap::fp8_encode_scaled,
        fp8_decode_scaled: avx2_wrap::fp8_decode_scaled,
        bf16_round: avx2_wrap::bf16_round,
        bf16_stochastic_round: avx2_wrap::bf16_stochastic_round,
        bf16_scaled_round: avx2_wrap::bf16_scaled_round,
        bf16_accumulate: avx2_wrap::bf16_accumulate,
        bf16_pack: avx2_wrap::bf16_pack,
        bf16_unpack: avx2_wrap::bf16_unpack,
        sr_reduce_block: avx2_wrap::sr_reduce_block,
        sumsq_lanes_into: avx2_wrap::sumsq_lanes_into,
        adamw_update: avx2_wrap::adamw_update,
    };
    check_backend_matches_scalar_spec(&fns);
    check_adamw_matches_spec(&fns);
}

/// Thin safe wrappers over the NEON kernels (NEON is mandatory on
/// aarch64, so these are always sound there).
#[cfg(target_arch = "aarch64")]
mod neon_wrap {
    use llmq::precision::backend::neon;
    use llmq::precision::{CounterRng, Fp8Format};

    pub fn absmax(x: &[f32]) -> f32 {
        unsafe { neon::absmax(x) }
    }
    pub fn fp8_round_scaled(f: Fp8Format, x: &mut [f32], s: f32) {
        unsafe { neon::fp8_round_scaled(f, x, s) }
    }
    pub fn fp8_encode_scaled(f: Fp8Format, x: &[f32], s: f32, o: &mut [u8]) {
        unsafe { neon::fp8_encode_scaled(f, x, s, o) }
    }
    pub fn fp8_decode_scaled(f: Fp8Format, b: &[u8], s: f32, o: &mut [f32]) {
        unsafe { neon::fp8_decode_scaled(f, b, s, o) }
    }
    pub fn bf16_round(x: &mut [f32]) {
        unsafe { neon::bf16_round(x) }
    }
    pub fn bf16_stochastic_round(x: &mut [f32], r: &CounterRng, c: u32) {
        unsafe { neon::bf16_stochastic_round(x, r, c) }
    }
    pub fn bf16_scaled_round(x: &[f32], o: &mut [f32], s: f32) {
        unsafe { neon::bf16_scaled_round(x, o, s) }
    }
    pub fn bf16_accumulate(a: &mut [f32], x: &[f32]) {
        unsafe { neon::bf16_accumulate(a, x) }
    }
    pub fn bf16_pack(x: &[f32], o: &mut [u16]) {
        unsafe { neon::bf16_pack(x, o) }
    }
    pub fn bf16_unpack(b: &[u16], o: &mut [f32]) {
        unsafe { neon::bf16_unpack(b, o) }
    }
    pub fn sr_reduce_block(
        s: &[&[f32]],
        base: usize,
        blk: &mut [f32],
        sc: Option<f32>,
        r: &CounterRng,
        c: u32,
    ) {
        unsafe { neon::sr_reduce_block(s, base, blk, sc, r, c) }
    }
    pub fn sumsq_lanes_into(x: &[f32], lanes: &mut [f64]) {
        unsafe { neon::sumsq_lanes_into(x, lanes) }
    }
    pub fn adamw_update(
        spec: &llmq::precision::backend::AdamWSpec,
        p: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        c: u32,
    ) {
        unsafe { neon::adamw_update(spec, p, m, v, g, c) }
    }
}

/// The NEON kernels pinned to the scalar spec, independent of dispatch.
#[cfg(target_arch = "aarch64")]
#[test]
fn neon_kernels_bit_identical_to_scalar_spec() {
    let fns = BackendFns {
        label: "neon",
        absmax: neon_wrap::absmax,
        fp8_round_scaled: neon_wrap::fp8_round_scaled,
        fp8_encode_scaled: neon_wrap::fp8_encode_scaled,
        fp8_decode_scaled: neon_wrap::fp8_decode_scaled,
        bf16_round: neon_wrap::bf16_round,
        bf16_stochastic_round: neon_wrap::bf16_stochastic_round,
        bf16_scaled_round: neon_wrap::bf16_scaled_round,
        bf16_accumulate: neon_wrap::bf16_accumulate,
        bf16_pack: neon_wrap::bf16_pack,
        bf16_unpack: neon_wrap::bf16_unpack,
        sr_reduce_block: neon_wrap::sr_reduce_block,
        sumsq_lanes_into: neon_wrap::sumsq_lanes_into,
        adamw_update: neon_wrap::adamw_update,
    };
    check_backend_matches_scalar_spec(&fns);
    check_adamw_matches_spec(&fns);
}

/// `AdamW::step` (parallel + SIMD-dispatched) vs the pure-scalar
/// `step_serial` oracle at lane-remainder lengths and 1/2/8 threads,
/// with IEEE specials planted in params and grads — the dispatch-level
/// face of the AdamW battery above.
#[test]
fn adamw_step_matches_scalar_serial_at_lane_remainders() {
    let opt = AdamW::new(AdamWParams::default());
    for n in SIMD_LENS {
        let p0 = simd_data(n, 0x9A);
        let m0 = data(n, 0x9B);
        let v0: Vec<f32> = data(n, 0x9C).iter().map(|x| x.abs()).collect();
        let g = simd_data(n, 0x9D);
        let (mut pr, mut mr, mut vr) = (p0.clone(), m0.clone(), v0.clone());
        opt.step_serial(&mut pr, &mut mr, &mut vr, &g, 1e-3, 7, 4321, n as u32 + 13);
        for t in THREAD_COUNTS {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            par::with_threads(t, || {
                opt.step(&mut p, &mut m, &mut v, &g, 1e-3, 7, 4321, n as u32 + 13)
            });
            assert_eq!(bits(&p), bits(&pr), "p n={n} t={t}");
            assert_eq!(bits(&m), bits(&mr), "m n={n} t={t}");
            assert_eq!(bits(&v), bits(&vr), "v n={n} t={t}");
        }
    }
}

/// The widened-grid norm sweep: `global_norm` and `fused::grad_norm`
/// are bit-identical (a) across 1/2/8 threads, (b) to their scalar-
/// kernel counterparts whatever backend dispatch resolves, and (c) to
/// an independent re-derivation of the Rule 2a two-level grid.
#[test]
fn widened_norm_grid_matches_scalar_spec_and_threads() {
    // lengths straddling both chunk grids (REDUCE_CHUNK 64K, PIPELINE
    // block 8K) and the 8-lane sub-grid
    for n in [0usize, 1, 7, 9, 8191, 8193, 65_537, 100_003] {
        let g = data(n, 0x6068);
        // independent spec: REDUCE_CHUNK chunks of 8-lane partials
        let spec_norm = |chunk: usize| -> f32 {
            let mut acc = 0.0f64;
            let mut s = 0usize;
            while s < n {
                let e = (s + chunk).min(n);
                acc += backend::fold_lanes(&sumsq_lanes_spec(&g[s..e]));
                s = e;
            }
            acc.sqrt() as f32
        };
        let want_global = spec_norm(par::REDUCE_CHUNK);
        let want_pipeline = spec_norm(llmq::collectives::memcpy::PIPELINE_BLOCK);
        let one = par::with_threads(1, || global_norm(&g));
        assert_eq!(one.to_bits(), want_global.to_bits(), "global spec n={n}");
        let pipe = par::with_threads(1, || llmq::optim::fused::grad_norm(&g));
        assert_eq!(pipe.to_bits(), want_pipeline.to_bits(), "pipeline spec n={n}");
        for t in THREAD_COUNTS {
            assert_eq!(
                par::with_threads(t, || global_norm(&g)).to_bits(),
                one.to_bits(),
                "global threads n={n} t={t}"
            );
            assert_eq!(
                par::with_threads(t, || llmq::optim::fused::grad_norm(&g)).to_bits(),
                pipe.to_bits(),
                "pipeline threads n={n} t={t}"
            );
            // dispatched kernel vs forced-scalar kernel on the same grid
            assert_eq!(
                par::with_threads(t, || llmq::optim::fused::grad_norm_scalar(&g)).to_bits(),
                pipe.to_bits(),
                "scalar-kernel pin n={n} t={t}"
            );
        }
    }
}

/// The parallel wrappers (now SIMD inside each chunk) still match their
/// serial references at every thread count for the lane-remainder sweep
/// — catches any interaction between `SIMD_ALIGN` chunking and kernels.
#[test]
fn parallel_simd_wrappers_match_serial_at_lane_remainders() {
    let rng = CounterRng::new(0x11A17);
    for n in SIMD_LENS {
        let base = simd_data(n, 0x77);
        let mut q_ref = base.clone();
        let s_ref = E4M3.quantize_serial(&mut q_ref);
        let mut sr_ref = base.clone();
        bf16::stochastic_round_slice_serial(&mut sr_ref, &rng, 31);
        for t in THREAD_COUNTS {
            let mut q = base.clone();
            let s = par::with_threads(t, || E4M3.quantize(&mut q));
            assert_eq!(s.to_bits(), s_ref.to_bits(), "scale n={n} t={t}");
            assert_eq!(bits(&q), bits(&q_ref), "quantize n={n} t={t}");

            let mut sr = base.clone();
            par::with_threads(t, || bf16::stochastic_round_slice(&mut sr, &rng, 31));
            assert_eq!(bits(&sr), bits(&sr_ref), "sr n={n} t={t}");
        }
    }
}
