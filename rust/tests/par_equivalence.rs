//! Parallelism-correctness suite for the parallel execution layer
//! (`util::par`): every parallel hot path must produce results
//! bit-identical to its single-threaded reference at 1, 2 and 8 worker
//! threads — including empty and non-chunk-aligned lengths. The one
//! documented exception is `global_norm`, whose fixed-grid tree
//! reduction is bit-identical *across thread counts* but only
//! ULP-bounded against the unchunked serial fold.

use llmq::collectives::{DeviceGroup, memcpy::reduce_scatter_memcpy_serial, reduce_scatter_memcpy};
use llmq::optim::{AdamW, AdamWParams, clip_global_norm, global_norm, global_norm_serial};
use llmq::precision::{bf16, CounterRng, E4M3, E5M2, fp8};
use llmq::util::par;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Test lengths: empty, single, sub-grain, non-aligned multi-chunk.
const LENS: [usize; 5] = [0, 1, 1023, 65_537, 100_003];

fn data(n: usize, salt: u32) -> Vec<f32> {
    let rng = CounterRng::new(salt);
    (0..n)
        .map(|i| (rng.next_f32(i as u32) - 0.5) * 16.0)
        .collect()
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn fp8_quantize_parallel_equivalence() {
    for fmt in [E4M3, E5M2] {
        for n in LENS {
            let base = data(n, 0xF8);
            let mut reference = base.clone();
            let s_ref = fmt.quantize_serial(&mut reference);
            for t in THREAD_COUNTS {
                let mut x = base.clone();
                let s = par::with_threads(t, || fmt.quantize(&mut x));
                assert_eq!(s.to_bits(), s_ref.to_bits(), "{} n={n} t={t}", fmt.name);
                assert_eq!(bits(&x), bits(&reference), "{} n={n} t={t}", fmt.name);
            }
        }
    }
}

#[test]
fn fp8_codec_roundtrip_parallel_equivalence() {
    for n in LENS {
        let base = data(n, 0xC0DE);
        let (b_ref, s_ref) = fp8::encode_tensor_serial(E4M3, &base);
        let mut d_ref = vec![0f32; n];
        fp8::decode_tensor_serial(E4M3, &b_ref, s_ref, &mut d_ref);
        for t in THREAD_COUNTS {
            let (bytes, scale) = par::with_threads(t, || fp8::encode_tensor(E4M3, &base));
            assert_eq!(bytes, b_ref, "encode n={n} t={t}");
            assert_eq!(scale.to_bits(), s_ref.to_bits());
            let mut dec = vec![0f32; n];
            par::with_threads(t, || fp8::decode_tensor(E4M3, &bytes, scale, &mut dec));
            assert_eq!(bits(&dec), bits(&d_ref), "decode n={n} t={t}");
        }
    }
}

#[test]
fn bf16_stochastic_round_parallel_equivalence() {
    let rng = CounterRng::new(0x11A17);
    for n in LENS {
        let base = data(n, 0xB16);
        for counter_base in [0u32, 977, u32::MAX - 5] {
            let mut reference = base.clone();
            bf16::stochastic_round_slice_serial(&mut reference, &rng, counter_base);
            for t in THREAD_COUNTS {
                let mut x = base.clone();
                par::with_threads(t, || bf16::stochastic_round_slice(&mut x, &rng, counter_base));
                assert_eq!(bits(&x), bits(&reference), "n={n} t={t} cb={counter_base}");
            }
        }
    }
}

#[test]
fn bf16_accumulate_parallel_equivalence() {
    for n in LENS {
        let base = data(n, 0xACC);
        let add = data(n, 0xADD);
        let mut reference = base.clone();
        bf16::accumulate_bf16_serial(&mut reference, &add);
        for t in THREAD_COUNTS {
            let mut acc = base.clone();
            par::with_threads(t, || bf16::accumulate_bf16(&mut acc, &add));
            assert_eq!(bits(&acc), bits(&reference), "n={n} t={t}");
        }
    }
}

#[test]
fn bf16_pack_unpack_parallel_equivalence() {
    for n in LENS {
        let mut base = data(n, 0xBA9);
        bf16::round_slice(&mut base);
        let mut packed_ref = vec![0u16; n];
        let mut packed = vec![0u16; n];
        // serial loop reference
        for (o, &v) in packed_ref.iter_mut().zip(&base) {
            *o = (v.to_bits() >> 16) as u16;
        }
        for t in THREAD_COUNTS {
            par::with_threads(t, || bf16::pack(&base, &mut packed));
            assert_eq!(packed, packed_ref, "pack n={n} t={t}");
            let mut un = vec![0f32; n];
            par::with_threads(t, || bf16::unpack(&packed, &mut un));
            assert_eq!(bits(&un), bits(&base), "unpack n={n} t={t}");
        }
    }
}

#[test]
fn adamw_step_parallel_equivalence() {
    let opt = AdamW::new(AdamWParams::default());
    for n in LENS {
        let p0 = data(n, 0x9A);
        let m0 = data(n, 0x9B);
        let v0: Vec<f32> = data(n, 0x9C).iter().map(|x| x.abs()).collect();
        let g = data(n, 0x9D);
        let run_serial = || {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            opt.step_serial(&mut p, &mut m, &mut v, &g, 1e-3, 7, 4321, n as u32 + 13);
            (p, m, v)
        };
        let (pr, mr, vr) = run_serial();
        for t in THREAD_COUNTS {
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            par::with_threads(t, || {
                opt.step(&mut p, &mut m, &mut v, &g, 1e-3, 7, 4321, n as u32 + 13)
            });
            assert_eq!(bits(&p), bits(&pr), "p n={n} t={t}");
            assert_eq!(bits(&m), bits(&mr), "m n={n} t={t}");
            assert_eq!(bits(&v), bits(&vr), "v n={n} t={t}");
        }
    }
}

#[test]
fn global_norm_identical_across_threads_and_ulp_close_to_serial() {
    for n in LENS {
        let g = data(n, 0x6068);
        let one = par::with_threads(1, || global_norm(&g));
        for t in THREAD_COUNTS {
            let norm = par::with_threads(t, || global_norm(&g));
            // fixed reduction grid → bit-identical for every thread count
            assert_eq!(norm.to_bits(), one.to_bits(), "n={n} t={t}");
        }
        let serial = global_norm_serial(&g);
        let tol = serial.abs() * 1e-6f32 + 1e-12f32;
        assert!(
            (one - serial).abs() <= tol,
            "n={n}: chunked {one} vs serial {serial}"
        );
    }
}

#[test]
fn clip_global_norm_parallel_equivalence() {
    let n = 100_003;
    let base = data(n, 0xC11F);
    let mut reference = base.clone();
    let pre_ref = {
        // reference: serial norm + serial scale
        let norm = par::with_threads(1, || global_norm(&reference));
        let max_norm = norm / 3.0;
        let s = max_norm / norm;
        for v in reference.iter_mut() {
            *v *= s;
        }
        (norm, max_norm)
    };
    for t in THREAD_COUNTS {
        let mut g = base.clone();
        let pre = par::with_threads(t, || clip_global_norm(&mut g, pre_ref.1));
        assert_eq!(pre.to_bits(), pre_ref.0.to_bits(), "pre-clip norm t={t}");
        assert_eq!(bits(&g), bits(&reference), "clipped grads t={t}");
    }
}

#[test]
fn reduce_scatter_parallel_equivalence() {
    // chunk sizes straddle the pipeline block (8192): unaligned + aligned
    for (world, chunk) in [(2usize, 5usize), (4, 1000), (2, 8192), (4, 20_011)] {
        let n = world * chunk;
        let rng = CounterRng::new(0x5CA7);
        let grads = DeviceGroup::from_fn(world, n, |r, i| {
            bf16::round_to_bf16((rng.next_f32((r * n + i) as u32) - 0.5) * 2.0)
        });
        let mk_acc = || -> Vec<Vec<f32>> {
            (0..world)
                .map(|w| {
                    (0..chunk)
                        .map(|i| bf16::round_to_bf16(rng.next_f32((w * chunk + i) as u32 ^ 0xACC)))
                        .collect()
                })
                .collect()
        };
        let mut reference = mk_acc();
        reduce_scatter_memcpy_serial(&grads, &mut reference, &CounterRng::new(3), 991);
        for t in THREAD_COUNTS {
            let mut acc = mk_acc();
            par::with_threads(t, || {
                reduce_scatter_memcpy(&grads, &mut acc, &CounterRng::new(3), 991)
            });
            for w in 0..world {
                assert_eq!(
                    bits(&acc[w]),
                    bits(&reference[w]),
                    "world={world} chunk={chunk} w={w} t={t}"
                );
            }
        }
    }
}

#[test]
fn all_gather_parallel_matches_any_thread_count() {
    for (world, chunk) in [(2usize, 7usize), (4, 3000), (6, 9001)] {
        let shards: Vec<Vec<f32>> = (0..world)
            .map(|r| (0..chunk).map(|i| (r * 100_000 + i) as f32).collect())
            .collect();
        let mut reference = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
        par::with_threads(1, || llmq::collectives::all_gather_memcpy(&shards, &mut reference));
        for t in THREAD_COUNTS {
            let mut out = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
            par::with_threads(t, || llmq::collectives::all_gather_memcpy(&shards, &mut out));
            assert_eq!(out.buffers, reference.buffers, "world={world} t={t}");
        }
    }
}
