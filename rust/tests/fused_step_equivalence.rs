//! Bit-identity suite for the fused streaming optimizer-step pipeline:
//! `optim::fused::fused_step` and its `exec` stream-program port
//! `fused_step_async` — the host step `Trainer::train_step` runs — must
//! be bitwise identical to the staged multi-pass reference
//! (`staged_step`, the `Trainer::train_step_staged` chain) at 1/2/8
//! worker threads and world ∈ {1, 2, 4}, including a clip-triggering
//! gradient scale and a non-`PIPELINE_BLOCK`-aligned parameter count.
//! The async rows run under whatever `LLMQ_ASYNC`/`LLMQ_STREAMS`
//! resolve (CI covers async-on, the `LLMQ_ASYNC=off` serial oracle, and
//! a 2-stream × 2-thread interleaving stress) plus an explicit
//! stream-count sweep.
//! The two Trainer entry points differ *only* in which of these two
//! functions they call after the (shared) microbatch loop, so this
//! covers the artifact-gated paths too.
//!
//! Since the staged reference runs the **scalar** norm and AdamW kernels
//! regardless of `LLMQ_SIMD`, fused-vs-staged equality here also pins
//! the vector AdamW and widened-grid norm kernels end to end: under the
//! default `LLMQ_SIMD=auto` the fused side dispatches AVX2/NEON, and CI
//! re-runs the suite under `LLMQ_SIMD=scalar` so the scalar-vs-scalar
//! pairing stays green too. The phase-level test at the bottom pins the
//! dispatched phase kernels against their `*_scalar` twins directly.

use llmq::collectives::memcpy::PIPELINE_BLOCK;
use llmq::exec;
use llmq::optim::fused::{
    fused_step, fused_step_async, grad_norm_scalar, norm_phase, reduce_phase, staged_step,
    update_phase, update_phase_scalar, HostStep,
};
use llmq::optim::{AdamWParams, MomentsMode};
use llmq::precision::{round_to_bf16, CounterRng, E5M2};
use llmq::train::StepWorkspace;
use llmq::util::par;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Which host-step implementation a matrix run exercises.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Path {
    /// Staged multi-pass scalar-kernel oracle.
    Staged,
    /// Synchronous fused streaming pipeline.
    Fused,
    /// The `exec` stream program (whatever `LLMQ_ASYNC` resolves to —
    /// CI runs the suite with the async workers on and with the serial
    /// oracle via `LLMQ_ASYNC=off`).
    Async,
}

fn host_step(grad_clip: f32, n_micro: usize, opt_world: usize) -> HostStep {
    HostStep {
        hp: AdamWParams::default(),
        lr: 3e-4,
        grad_clip,
        step: 2, // exercise bias correction past step 1
        counter: 12_345,
        seed: 9,
        n_micro,
        opt_world,
        moments: MomentsMode::Fp32,
    }
}

/// Fill the workspace accumulators with deterministic bf16-grid noise of
/// the given amplitude (amplitude controls whether the clip triggers).
fn fill_dev_grads(ws: &mut StepWorkspace, salt: u32, amp: f32) {
    let n = ws.n();
    let rng = CounterRng::new(salt);
    for (d, g) in ws.dev_grads.iter_mut().enumerate() {
        for (i, x) in g.iter_mut().enumerate() {
            *x = round_to_bf16((rng.next_f32((d * n + i) as u32) - 0.5) * amp);
        }
    }
}

fn init_state(n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let p = (0..n)
        .map(|i| round_to_bf16(0.02 * (i % 101) as f32 - 1.0))
        .collect();
    // Non-zero bf16-grid moments: a harder target than the cold start.
    let m = (0..n)
        .map(|i| round_to_bf16(0.001 * (i % 13) as f32 - 0.006))
        .collect();
    let v = (0..n).map(|i| round_to_bf16(1e-4 * (i % 7) as f32)).collect();
    (p, m, v)
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Run one path at a thread count; returns (norm_bits, p, m, v).
fn run(
    path: Path,
    world: usize,
    n: usize,
    threads: usize,
    amp: f32,
    hs: &HostStep,
) -> (u32, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut ws = StepWorkspace::new(world, n);
    ws.begin_step();
    fill_dev_grads(&mut ws, 0xACC, amp);
    let (mut p, mut m, mut v) = init_state(n);
    let norm = par::with_threads(threads, || match path {
        Path::Staged => staged_step(&mut ws, &mut p, &mut m, &mut v, hs),
        Path::Fused => fused_step(&mut ws, &mut p, &mut m, &mut v, hs),
        Path::Async => fused_step_async(&mut ws, &mut p, &mut m, &mut v, hs),
    });
    if path != Path::Staged && world > 1 {
        // the fused gather must leave every replica equal to the params
        for r in &ws.rank_params {
            assert_eq!(bits(r), bits(&p), "replica != params");
        }
    }
    (norm.to_bits(), p, m, v)
}

fn assert_matrix(
    n_for: impl Fn(usize) -> usize,
    amp: f32,
    clip: f32,
    expect_clip: bool,
    moments: MomentsMode,
) {
    for world in [1usize, 2, 4] {
        let n = n_for(world);
        assert_eq!(n % world, 0, "test geometry");
        for opt_world in [1usize, 4] {
            let hs = HostStep {
                moments,
                ..host_step(clip, 3 * world, opt_world)
            };
            let reference = run(Path::Staged, world, n, 1, amp, &hs);
            let norm = f32::from_bits(reference.0);
            assert_eq!(
                norm > clip && norm > 0.0,
                expect_clip,
                "clip precondition: norm {norm} vs clip {clip} (world {world})"
            );
            for t in THREAD_COUNTS {
                for path in [Path::Staged, Path::Fused, Path::Async] {
                    let got = run(path, world, n, t, amp, &hs);
                    assert_eq!(
                        got.0, reference.0,
                        "{path:?} norm, world {world} opt {opt_world} t {t}"
                    );
                    assert_eq!(
                        bits(&got.1),
                        bits(&reference.1),
                        "{path:?} params, world {world} opt {opt_world} t {t}"
                    );
                    assert_eq!(bits(&got.2), bits(&reference.2), "{path:?} m");
                    assert_eq!(bits(&got.3), bits(&reference.3), "{path:?} v");
                }
            }
        }
    }
}

#[test]
fn fused_matches_staged_no_clip() {
    // small gradients: the clip never triggers
    assert_matrix(|_| 2 * PIPELINE_BLOCK, 0.02, 1.0, false, MomentsMode::Fp32);
}

#[test]
fn fused_matches_staged_with_clip_triggered() {
    // large gradients: global norm far above the clip threshold
    assert_matrix(|_| 2 * PIPELINE_BLOCK, 4.0, 0.5, true, MomentsMode::Fp32);
}

#[test]
fn fused_matches_staged_unaligned_n() {
    // n divisible by every world/opt_world in the matrix but not by
    // PIPELINE_BLOCK: the last pipeline chunk is a partial block.
    assert_matrix(|_| 3 * PIPELINE_BLOCK + 64, 0.05, 1.0, false, MomentsMode::Fp32);
}

#[test]
fn fused_is_deterministic_across_repeats() {
    let hs = host_step(1.0, 6, 4);
    for path in [Path::Fused, Path::Async] {
        let a = run(path, 2, PIPELINE_BLOCK + 128, 8, 0.1, &hs);
        let b = run(path, 2, PIPELINE_BLOCK + 128, 8, 0.1, &hs);
        assert_eq!(a.0, b.0, "{path:?}");
        assert_eq!(bits(&a.1), bits(&b.1), "{path:?}");
        assert_eq!(bits(&a.2), bits(&b.2), "{path:?}");
        assert_eq!(bits(&a.3), bits(&b.3), "{path:?}");
    }
}


/// The full path × world × clip matrix again with fp8(m)/bf16(v)
/// moment storage: fused and async pinned bitwise to the scalar staged
/// quantized oracle. Only the first-moment SR grid changes, so this
/// isolates the e5m2 moment codec inside the phase-3 chunk kernel.
#[test]
fn fused_matches_staged_fp8_moments_no_clip() {
    assert_matrix(|_| 2 * PIPELINE_BLOCK, 0.02, 1.0, false, MomentsMode::Fp8);
}

#[test]
fn fused_matches_staged_fp8_moments_with_clip_triggered() {
    assert_matrix(|_| 2 * PIPELINE_BLOCK, 4.0, 0.5, true, MomentsMode::Fp8);
}

#[test]
fn fused_matches_staged_fp8_moments_unaligned_n() {
    assert_matrix(|_| 3 * PIPELINE_BLOCK + 64, 0.05, 1.0, false, MomentsMode::Fp8);
}

/// Under fp8 moment storage every stored first moment must land exactly
/// on the e5m2 grid (that is what makes the 1-byte checkpoint and
/// planner byte model lossless), while `v` stays on the bf16 grid.
#[test]
fn fp8_moments_land_on_the_e5m2_grid() {
    let hs = HostStep {
        moments: MomentsMode::Fp8,
        ..host_step(1.0, 6, 4)
    };
    let (_, _, m, v) = run(Path::Fused, 2, 2 * PIPELINE_BLOCK, 8, 0.1, &hs);
    for &x in &m {
        assert_eq!(x, E5M2.round(x), "m not on the e5m2 grid: {x}");
    }
    for &x in &v {
        assert_eq!(x, round_to_bf16(x), "v not on the bf16 grid: {x}");
    }
}

/// The async path across explicit stream counts (independent of the
/// `LLMQ_STREAMS` env): every stream schedule lands on the staged
/// reference bits.
#[test]
fn async_stream_count_is_unobservable() {
    let n = 2 * PIPELINE_BLOCK + 64;
    let hs = host_step(1.0, 6, 4);
    let reference = run(Path::Staged, 2, n, 1, 0.1, &hs);
    for streams in [1usize, 2, 3, 8] {
        let got = exec::with_streams(streams, || run(Path::Async, 2, n, 8, 0.1, &hs));
        assert_eq!(got.0, reference.0, "streams {streams}");
        assert_eq!(bits(&got.1), bits(&reference.1), "streams {streams}");
        assert_eq!(bits(&got.2), bits(&reference.2), "streams {streams}");
        assert_eq!(bits(&got.3), bits(&reference.3), "streams {streams}");
    }
}

/// The dispatched phase-2 (widened-grid norm) and phase-3 (fused
/// clip+AdamW+SR) kernels vs their forced-scalar twins, at 1/2/8
/// threads and a clip-triggering norm — a direct scalar-vs-vector pin
/// that holds whatever `LLMQ_SIMD` resolves (trivially when dispatch is
/// already scalar; CI runs the suite both ways).
#[test]
fn fused_phases_match_scalar_kernels() {
    let n = 3 * PIPELINE_BLOCK + 64;
    for (amp, clip) in [(0.05f32, 1.0f32), (4.0, 0.5)] {
        let hs = host_step(clip, 6, 4);
        let mut ws = StepWorkspace::new(2, n);
        ws.begin_step();
        fill_dev_grads(&mut ws, 0xACC, amp);
        par::with_threads(1, || reduce_phase(&mut ws, &hs));
        let norm_ref = par::with_threads(1, || grad_norm_scalar(&ws.grads));
        let (p0, m0, v0) = init_state(n);
        let mut want = (p0.clone(), m0.clone(), v0.clone());
        par::with_threads(1, || {
            update_phase_scalar(&mut ws, &mut want.0, &mut want.1, &mut want.2, &hs, norm_ref)
        });
        for t in THREAD_COUNTS {
            let norm = par::with_threads(t, || norm_phase(&mut ws));
            assert_eq!(norm.to_bits(), norm_ref.to_bits(), "norm amp={amp} t={t}");
            let mut got = (p0.clone(), m0.clone(), v0.clone());
            par::with_threads(t, || {
                update_phase(&mut ws, &mut got.0, &mut got.1, &mut got.2, &hs, norm)
            });
            assert_eq!(bits(&got.0), bits(&want.0), "p amp={amp} t={t}");
            assert_eq!(bits(&got.1), bits(&want.1), "m amp={amp} t={t}");
            assert_eq!(bits(&got.2), bits(&want.2), "v amp={amp} t={t}");
        }
    }
}

/// The fused stream program's declared access sets pass full static
/// race verification (`exec::verify` happens-before over per-stream
/// vector clocks) at every stream count, both with the `LLMQ_VERIFY`
/// scope-exit hook live and over the recorded trace after the fact —
/// and recording + verification change none of the numbers.
#[test]
fn fused_stream_program_is_statically_race_free() {
    let n = 2 * PIPELINE_BLOCK + 64;
    let hs = host_step(1.0, 4, 2);
    let reference = run(Path::Fused, 2, n, 1, 0.05, &hs);
    for streams in [1usize, 2, 4] {
        let mut ws = StepWorkspace::new(2, n);
        ws.begin_step();
        fill_dev_grads(&mut ws, 0xACC, 0.05);
        let (mut p, mut m, mut v) = init_state(n);
        let (norm, trace) = exec::with_async(true, || {
            exec::with_verify(true, || {
                exec::with_streams(streams, || {
                    llmq::optim::fused::fused_step_async_traced(&mut ws, &mut p, &mut m, &mut v, &hs)
                })
            })
        });
        llmq::sim::verify_trace(&trace)
            .unwrap_or_else(|e| panic!("streams={streams}: {e}"));
        assert_eq!(norm.to_bits(), reference.0, "norm streams={streams}");
        assert_eq!(bits(&p), bits(&reference.1), "p streams={streams}");
    }
}
