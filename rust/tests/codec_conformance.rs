//! Codec conformance suite: golden-vector batteries and seeded property
//! sweeps pinning every storage codec — fp8 (e4m3 / e5m2), bf16, and the
//! block-scaled mx/e2m1 tier — to the scalar reference loops in
//! `precision::backend::scalar` (the spec, per NUMERICS.md Rules 1 and 7).
//!
//! Three layers of pinning:
//!   1. Hand-computed golden vectors (IEEE specials, denormals, ±0,
//!      absmax ties, block-boundary lengths) checked bit-exact against
//!      the scalar loops.
//!   2. The dispatch entry points AND the raw AVX2/NEON kernels checked
//!      bit-identical to scalar at every boundary length — the arch
//!      kernels are exercised directly (behind a runtime feature probe),
//!      not just through whatever `LLMQ_SIMD` resolved.
//!   3. Seeded (murmur3-derived counter RNG) property sweeps: round-trip
//!      error bounded by the grid's scaled ULP, stochastic-rounding
//!      expectation unbiased over counter sweeps, and encode bitwise
//!      invariant across 1/2/8 threads × scalar/auto SIMD × async
//!      on/off. CI re-runs this binary under `LLMQ_SIMD=scalar|auto` ×
//!      `LLMQ_THREADS=1|8` so the env-level matrix is covered too.

use llmq::exec;
use llmq::precision::backend::{self, scalar};
use llmq::precision::fp8::stochastic_round_fp8;
use llmq::precision::{bf16, mx, CounterRng, Fp8Format, E2M1, E4M3, E5M2, MX_BLOCK};
use llmq::util::par;

/// The block-boundary length battery from the issue: empty, single
/// element, one short block, exactly one block, one block + 1, and a
/// many-block tensor with a one-element tail (2048 blocks + 1).
const LENS: [usize; 6] = [0, 1, 31, 32, 33, 65_537];

/// Seeded input in roughly [-8, 8] — `CounterRng` is the murmur3
/// finalizer, so this is the "murmur3-derived" stream of the issue.
fn seeded(n: usize, key: u32) -> Vec<f32> {
    let rng = CounterRng::new(key);
    (0..n)
        .map(|i| (rng.next_f32(i as u32) - 0.5) * 16.0)
        .collect()
}

/// Sprinkle IEEE specials over a seeded vector at fixed strides so the
/// conformance sweeps also cover NaN / ±inf / ±0 / denormal lanes.
fn with_specials(mut x: Vec<f32>) -> Vec<f32> {
    let specials = [
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        0.0,
        -0.0,
        f32::from_bits(1),          // smallest positive denormal
        -f32::from_bits(0x7F_FFFF), // largest negative denormal
        f32::MIN_POSITIVE,
        f32::MAX,
    ];
    for (k, s) in specials.iter().enumerate() {
        let idx = k * 7 + 3;
        if idx < x.len() {
            x[idx] = *s;
        }
    }
    x
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// Golden vectors: e2m1 code table and rounding
// ---------------------------------------------------------------------------

/// Every 4-bit e2m1 code decodes to its hand-computed grid value, and
/// every grid value encodes back to its code (sign at bit 3).
#[test]
fn golden_e2m1_code_table() {
    let expect = [
        (0x0u8, 0.0f32),
        (0x1, 0.5),
        (0x2, 1.0),
        (0x3, 1.5),
        (0x4, 2.0),
        (0x5, 3.0),
        (0x6, 4.0),
        (0x7, 6.0),
        (0x8, -0.0),
        (0x9, -0.5),
        (0xA, -1.0),
        (0xB, -1.5),
        (0xC, -2.0),
        (0xD, -3.0),
        (0xE, -4.0),
        (0xF, -6.0),
    ];
    for (code, val) in expect {
        assert_eq!(
            mx::e2m1_decode(code).to_bits(),
            val.to_bits(),
            "decode({code:#x})"
        );
        assert_eq!(mx::e2m1_encode(val), code, "encode({val})");
        // the high nibble is ignored on decode
        assert_eq!(
            mx::e2m1_decode(code | 0xF0).to_bits(),
            val.to_bits(),
            "decode({code:#x} | 0xF0)"
        );
    }
    // e2m1 has no NaN encoding: NaN stores code 0 (+0.0)
    assert_eq!(mx::e2m1_encode(f32::NAN), 0);
}

/// RNE onto the e2m1 grid: hand-computed table including every
/// tie-to-even case, saturation, and the IEEE specials.
#[test]
fn golden_e2m1_rounding() {
    let cases = [
        (0.0f32, 0.0f32),
        (0.2, 0.0),   // below the 0.25 midpoint
        (0.25, 0.0),  // tie between 0 and 0.5 -> even (0)
        (0.3, 0.5),
        (0.75, 1.0),  // tie between 0.5 and 1.0 -> even (1.0)
        (1.25, 1.0),  // tie between 1.0 and 1.5 -> even (1.0)
        (1.75, 2.0),  // tie between 1.5 and 2.0 -> even (2.0)
        (2.5, 2.0),   // tie between 2 and 3 -> even (2)
        (3.5, 4.0),   // tie between 3 and 4 -> even (4)
        (5.0, 4.0),   // tie between 4 and 6 -> even (4)
        (5.25, 6.0),
        (6.0, 6.0),
        (7.0, 6.0),             // saturate
        (f32::INFINITY, 6.0),   // saturate
        (f32::MAX, 6.0),
        (f32::from_bits(1), 0.0), // denormal underflows to zero
    ];
    for (x, want) in cases {
        assert_eq!(E2M1.round(x).to_bits(), want.to_bits(), "round({x})");
        if x != 0.0 {
            // negatives mirror (a negative input that underflows keeps
            // its sign: round(-0.2) is -0.0)
            assert_eq!(E2M1.round(-x).to_bits(), (-want).to_bits(), "round({})", -x);
        }
    }
    assert!(E2M1.round(f32::NAN).is_nan());
    // -0.0 rounds to +0.0 (the round path drops the zero's sign)
    assert_eq!(E2M1.round(-0.0).to_bits(), 0.0f32.to_bits());
}

/// e8m0 scale selection and decode: hand-computed byte per absmax. The
/// invariant: `absmax / scale` lands in [4, 8) (the top e2m1 binade),
/// with all-zero, denormal and infinite absmax clamped as documented.
#[test]
fn golden_e8m0_scale_bytes() {
    let cases = [
        (0.0f32, 127u8),              // all-zero block: scale 1.0
        (1.0, 125),                   // scale 0.25 -> 1.0/0.25 = 4.0
        (4.0, 127),                   // scale 1.0
        (6.0, 127),                   // scale 1.0 -> 6.0 in [4, 8)
        (7.99, 127),                  // still the same binade
        (8.0, 128),                   // scale 2.0
        (15.5, 128),                  // scale 2.0 -> 7.75
        (448.0, 133),                 // scale 64 -> 7.0
        (f32::INFINITY, 254),         // clamp to the largest scale 2^127
        (f32::MAX, 252),              // scale 2^125
        (f32::from_bits(1), 0),       // denormal absmax: smallest scale
        (f32::MIN_POSITIVE, 0),       // 2^-126: exponent clamps to -127
    ];
    for (amax, byte) in cases {
        assert_eq!(mx::e8m0_from_absmax(amax), byte, "scale byte for {amax}");
    }
    // decode is the exact power of two (byte 0 is an f32 subnormal)
    assert_eq!(mx::e8m0_decode(127).to_bits(), 1.0f32.to_bits());
    assert_eq!(mx::e8m0_decode(125).to_bits(), 0.25f32.to_bits());
    assert_eq!(mx::e8m0_decode(128).to_bits(), 2.0f32.to_bits());
    assert_eq!(mx::e8m0_decode(254).to_bits(), 2.0f32.powi(127).to_bits());
    assert_eq!(mx::e8m0_decode(0).to_bits(), 0x0040_0000); // 2^-127
    assert!(mx::e8m0_decode(255).is_nan()); // e8m0 NaN code
    // sanity: every produced byte decodes so absmax/scale is in [4, 8)
    for amax in [0.5f32, 1.0, 3.0, 4.0, 6.0, 100.0, 1e30] {
        let s = mx::e8m0_decode(mx::e8m0_from_absmax(amax));
        let u = amax / s;
        assert!((4.0..8.0).contains(&u), "absmax {amax} -> u {u}");
    }
}

// ---------------------------------------------------------------------------
// Golden vectors: whole mx blocks through the scalar spec
// ---------------------------------------------------------------------------

/// One short block, hand-encoded end to end: scale from the absmax, every
/// element RNE onto the scaled grid. Also pins the absmax-tie case (+5
/// vs -5 tie for absmax — sign is dropped, the scale is the same either
/// way) and NaN flush-to-zero.
#[test]
fn golden_mx_single_block() {
    // absmax 7.0 -> scale byte 127 (scale 1.0)
    let x = [6.0f32, -6.0, 3.0, -0.5, 0.25, 0.3, 7.0, 0.0];
    let mut scales = [0u8; 1];
    let mut codes = [0u8; 8];
    scalar::mx_encode_rne(&x, &mut scales, &mut codes);
    assert_eq!(scales, [127]);
    assert_eq!(codes, [0x7, 0xF, 0x5, 0x9, 0x0, 0x1, 0x7, 0x0]);
    let mut out = [0.0f32; 8];
    scalar::mx_decode(&scales, &codes, &mut out);
    assert_eq!(out, [6.0, -6.0, 3.0, -0.5, 0.0, 0.5, 6.0, 0.0]);

    // absmax tie: +5 and -5 tie for the block absmax; sign is dropped
    let tie = [-5.0f32, 5.0, 0.0, 0.0];
    let (mut s2, mut c2) = ([0u8; 1], [0u8; 4]);
    scalar::mx_encode_rne(&tie, &mut s2, &mut c2);
    assert_eq!(s2, [127]); // absmax 5.0 -> scale 1.0
    // 5.0/1.0 = 5 ties between 4 and 6 -> even (4)
    assert_eq!(c2, [0xE, 0x6, 0x0, 0x0]);

    // NaN inside a block: ignored by the absmax fold, stored as code 0
    let nan = [f32::NAN, 4.0, -0.0, 0.0];
    let (mut s3, mut c3) = ([0u8; 1], [0u8; 4]);
    scalar::mx_encode_rne(&nan, &mut s3, &mut c3);
    assert_eq!(s3, [127]); // scale from absmax 4.0
    assert_eq!(c3, [0x0, 0x6, 0x0, 0x0]); // NaN and -0.0 both store 0

    // an all-infinite block: scale clamps to 2^127, codes saturate to 6,
    // and the decode overflows back to infinity
    let inf = [f32::INFINITY, f32::NEG_INFINITY];
    let (mut s4, mut c4) = ([0u8; 1], [0u8; 2]);
    scalar::mx_encode_rne(&inf, &mut s4, &mut c4);
    assert_eq!(s4, [254]);
    assert_eq!(c4, [0x7, 0xF]);
    let mut o4 = [0.0f32; 2];
    scalar::mx_decode(&s4, &c4, &mut o4);
    assert_eq!(o4[0], f32::INFINITY); // 6 * 2^127 overflows f32
    assert_eq!(o4[1], f32::NEG_INFINITY);
}

/// The worked 33-element example of NUMERICS.md Rule 7: block 0 selects
/// its scale from elements 0..32, the one-element block 1 from element
/// 32 alone. Every code is hand-computed.
#[test]
fn golden_mx_33_element_worked_example() {
    // x[i] = i/2 for i in 0..32 (absmax 15.5 -> scale 2.0), x[32] = -0.5
    // (absmax 0.5 -> scale 0.125; -0.5/0.125 = -4).
    let mut x: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
    x.push(-0.5);
    let mut scales = [0u8; 2];
    let mut codes = [0u8; 33];
    scalar::mx_encode_rne(&x, &mut scales, &mut codes);
    assert_eq!(scales, [128, 124], "block scales: 2.0 and 0.125");
    #[rustfmt::skip]
    let want: [u8; 33] = [
        // u = i/4 rounded onto {0,.5,1,1.5,2,3,4,6}, ties to even
        0, 0, 1, 2, 2, 2, 3, 4,     // u = 0.00 .. 1.75
        4, 4, 4, 5, 5, 5, 6, 6,     // u = 2.00 .. 3.75
        6, 6, 6, 6, 6, 7, 7, 7,     // u = 4.00 .. 5.75
        7, 7, 7, 7, 7, 7, 7, 7,     // u = 6.00 .. 7.75 (saturate at 6)
        0xE,                        // block 1: -0.5/0.125 = -4.0
    ];
    assert_eq!(codes, want);
    let mut out = [0.0f32; 33];
    scalar::mx_decode(&scales, &codes, &mut out);
    assert_eq!(out[0], 0.0);
    assert_eq!(out[2], 1.0); // code 1 = 0.5, times scale 2.0
    assert_eq!(out[31], 12.0); // saturated: 6 * 2.0
    assert_eq!(out[32], -0.5); // block 1 decodes with its own scale
}

/// Nibble packing round-trips at even and odd lengths, with element 2k
/// in the low nibble of byte k.
#[test]
fn golden_nibble_packing() {
    let codes = [0x7u8, 0xF, 0x5, 0x9, 0x1];
    let packed = mx::pack_nibbles(&codes);
    assert_eq!(packed, vec![0xF7, 0x95, 0x01]);
    assert_eq!(mx::unpack_nibbles(&packed, 5), codes.to_vec());
    assert_eq!(mx::pack_nibbles(&[]), Vec::<u8>::new());
    for n in [0usize, 1, 31, 32, 33] {
        let cs: Vec<u8> = (0..n).map(|i| (i % 16) as u8).collect();
        assert_eq!(mx::unpack_nibbles(&mx::pack_nibbles(&cs), n), cs);
    }
}

// ---------------------------------------------------------------------------
// Golden vectors: fp8 (e4m3 / e5m2) and bf16
// ---------------------------------------------------------------------------

/// Hand-computed e4m3 byte codes through the scalar encode/decode loops:
/// specials, saturation, denormals and tie-to-even.
#[test]
fn golden_e4m3_vectors() {
    // (input, byte, decoded grid value) at scale 1.0
    let cases: &[(f32, u8, f32)] = &[
        (0.0, 0x00, 0.0),
        (-0.0, 0x00, 0.0),            // round drops the zero's sign
        (1.0, 0x38, 1.0),
        (-1.0, 0xB8, -1.0),
        (448.0, 0x7E, 448.0),          // e4m3 max
        (500.0, 0x7E, 448.0),          // saturate
        (f32::INFINITY, 0x7E, 448.0),  // saturate
        (f32::NEG_INFINITY, 0xFE, -448.0),
        (0.001953125, 0x01, 0.001953125),  // 2^-9: smallest denormal
        (0.0009765625, 0x00, 0.0),     // 2^-10 ties down to zero (even)
        (0.0029296875, 0x02, 0.00390625), // 3*2^-10 ties up to 2^-8
        (1.0625, 0x38, 1.0),           // tie at 8.5 ulp -> even (8)
        (1.1875, 0x3A, 1.25),          // tie at 9.5 ulp -> even (10)
    ];
    for &(x, byte, dec) in cases {
        let mut out = [0u8; 1];
        scalar::fp8_encode_scaled(E4M3, &[x], 1.0, &mut out);
        assert_eq!(out[0], byte, "e4m3 encode({x})");
        let mut back = [0.0f32; 1];
        scalar::fp8_decode_scaled(E4M3, &out, 1.0, &mut back);
        assert_eq!(back[0].to_bits(), dec.to_bits(), "e4m3 decode({byte:#x})");
    }
    // NaN has the canonical all-ones code
    let mut out = [0u8; 1];
    scalar::fp8_encode_scaled(E4M3, &[f32::NAN], 1.0, &mut out);
    assert_eq!(out[0], 0x7F);
    // scaled path: encode(x/scale), decode multiplies back
    let mut o = [0u8; 1];
    scalar::fp8_encode_scaled(E4M3, &[3.0], 0.5, &mut o);
    assert_eq!(o[0], 0x4C); // round(6.0) = 6.0 = 1.5 * 2^2
    let mut b = [0.0f32; 1];
    scalar::fp8_decode_scaled(E4M3, &o, 0.5, &mut b);
    assert_eq!(b[0], 3.0);
}

/// Hand-computed e5m2 byte codes: the gradient format's wider exponent,
/// max 57344, denormal floor 2^-16.
#[test]
fn golden_e5m2_vectors() {
    let denorm = 2.0f32.powi(-16); // e5m2's smallest denormal step
    let cases: &[(f32, u8, f32)] = &[
        (0.0, 0x00, 0.0),
        (1.0, 0x3C, 1.0),
        (-1.5, 0xBE, -1.5),
        (57344.0, 0x7B, 57344.0),      // e5m2 max = 1.75 * 2^15
        (1.0e9, 0x7B, 57344.0),        // saturate
        (f32::INFINITY, 0x7B, 57344.0),
        (denorm, 0x01, denorm),
        (1.125, 0x3C, 1.0),            // tie at 4.5 ulp -> even (4)
        (1.375, 0x3E, 1.5),            // tie at 5.5 ulp -> even (6)
    ];
    for &(x, byte, dec) in cases {
        let mut out = [0u8; 1];
        scalar::fp8_encode_scaled(E5M2, &[x], 1.0, &mut out);
        assert_eq!(out[0], byte, "e5m2 encode({x})");
        let mut back = [0.0f32; 1];
        scalar::fp8_decode_scaled(E5M2, &out, 1.0, &mut back);
        assert_eq!(back[0].to_bits(), dec.to_bits(), "e5m2 decode({byte:#x})");
    }
}

/// bf16 RNE golden vectors: tie-to-even on the 16-bit boundary, sign of
/// zero preserved, NaN preserved, f32::MAX overflowing to infinity.
#[test]
fn golden_bf16_vectors() {
    let cases: &[(u32, u32)] = &[
        (0x3F80_0000, 0x3F80_0000), // 1.0 -> 1.0
        (0x3F80_8000, 0x3F80_0000), // 1 + 2^-8: tie -> even (1.0)
        (0x3F81_8000, 0x3F82_0000), // 1 + 3*2^-8: tie -> even (1.015625)
        (0x3F80_8001, 0x3F81_0000), // just above the tie -> up
        (0x8000_0000, 0x8000_0000), // -0.0 preserved
        (0x0000_0001, 0x0000_0000), // tiny denormal underflows to +0
        (0x7F80_0000, 0x7F80_0000), // +inf preserved
        (0x7F7F_FFFF, 0x7F80_0000), // f32::MAX rounds up to +inf
    ];
    for &(input, want) in cases {
        let got = llmq::precision::round_to_bf16(f32::from_bits(input));
        assert_eq!(got.to_bits(), want, "bf16({input:#010x})");
    }
    assert!(llmq::precision::round_to_bf16(f32::NAN).is_nan());
    // pack/unpack round-trips the high 16 bits exactly
    let vals = [1.0f32, -2.5, 0.15625, -0.0, f32::INFINITY];
    let mut packed = [0u16; 5];
    bf16::pack(&vals, &mut packed);
    assert_eq!(packed, [0x3F80, 0xC020, 0x3E20, 0x8000, 0x7F80]);
    let mut un = [0.0f32; 5];
    bf16::unpack(&packed, &mut un);
    assert_eq!(bits(&un), bits(&vals));
}

// ---------------------------------------------------------------------------
// Dispatch and raw arch kernels pinned to scalar at every length
// ---------------------------------------------------------------------------

/// The codec kernel surface under test, so the scalar / dispatch / raw
/// AVX2 / raw NEON tiers run the identical battery.
struct CodecFns {
    label: &'static str,
    absmax: fn(&[f32]) -> f32,
    fp8_encode_scaled: fn(Fp8Format, &[f32], f32, &mut [u8]),
    fp8_decode_scaled: fn(Fp8Format, &[u8], f32, &mut [f32]),
    mx_encode_rne: fn(&[f32], &mut [u8], &mut [u8]),
    mx_encode_sr: fn(&[f32], &mut [u8], &mut [u8], &CounterRng, u32),
    mx_decode: fn(&[u8], &[u8], &mut [f32]),
}

/// Run the full boundary-length battery (seeded data + IEEE specials)
/// through `fns` and require bitwise equality with the scalar spec.
fn check_codec_matches_scalar_spec(fns: &CodecFns) {
    let rng = CounterRng::new(0xC0DEC);
    for (li, &n) in LENS.iter().enumerate() {
        let x = with_specials(seeded(n, 0xABC0 + li as u32));
        let ctx = |what: &str| format!("{} {what} n={n}", fns.label);

        assert_eq!(
            (fns.absmax)(&x).to_bits(),
            scalar::absmax(&x).to_bits(),
            "{}",
            ctx("absmax")
        );

        for fmt in [E4M3, E5M2] {
            for scale in [1.0f32, 0.0625, 32.0] {
                let (mut a, mut b) = (vec![0u8; n], vec![0u8; n]);
                (fns.fp8_encode_scaled)(fmt, &x, scale, &mut a);
                scalar::fp8_encode_scaled(fmt, &x, scale, &mut b);
                assert_eq!(a, b, "{} s={scale}", ctx(fmt.name));
                let (mut da, mut db) = (vec![0.0f32; n], vec![0.0f32; n]);
                (fns.fp8_decode_scaled)(fmt, &a, scale, &mut da);
                scalar::fp8_decode_scaled(fmt, &b, scale, &mut db);
                assert_eq!(bits(&da), bits(&db), "{} s={scale}", ctx(fmt.name));
            }
        }

        let blocks = mx::blocks_of(n);
        let (mut sa, mut ca) = (vec![0u8; blocks], vec![0u8; n]);
        let (mut sb, mut cb) = (vec![0u8; blocks], vec![0u8; n]);
        (fns.mx_encode_rne)(&x, &mut sa, &mut ca);
        scalar::mx_encode_rne(&x, &mut sb, &mut cb);
        assert_eq!(sa, sb, "{}", ctx("mx scales"));
        assert_eq!(ca, cb, "{}", ctx("mx codes"));

        // SR at a plain base and at a wrapping counter base
        for base in [0u32, 0x1234_5678, u32::MAX - 7] {
            let (mut sa, mut ca) = (vec![0u8; blocks], vec![0u8; n]);
            let (mut sb, mut cb) = (vec![0u8; blocks], vec![0u8; n]);
            (fns.mx_encode_sr)(&x, &mut sa, &mut ca, &rng, base);
            scalar::mx_encode_sr(&x, &mut sb, &mut cb, &rng, base);
            assert_eq!(sa, sb, "{} base={base}", ctx("mx sr scales"));
            assert_eq!(ca, cb, "{} base={base}", ctx("mx sr codes"));
        }

        let (mut oa, mut ob) = (vec![0.0f32; n], vec![0.0f32; n]);
        (fns.mx_decode)(&sa, &ca, &mut oa);
        scalar::mx_decode(&sb, &cb, &mut ob);
        assert_eq!(bits(&oa), bits(&ob), "{}", ctx("mx decode"));
    }
}

/// Whatever backend `LLMQ_SIMD` resolved (CI runs both `scalar` and
/// `auto`), the dispatch entry points are bit-identical to the scalar
/// spec at every boundary length.
#[test]
fn dispatch_codecs_bit_identical_to_scalar_spec() {
    check_codec_matches_scalar_spec(&CodecFns {
        label: "dispatch",
        absmax: backend::absmax,
        fp8_encode_scaled: backend::fp8_encode_scaled,
        fp8_decode_scaled: backend::fp8_decode_scaled,
        mx_encode_rne: backend::mx_encode_rne,
        mx_encode_sr: backend::mx_encode_sr,
        mx_decode: backend::mx_decode,
    });
}

/// Thin safe wrappers over the raw AVX2 codec kernels — sound only after
/// the feature probe in the test below has confirmed AVX2.
#[cfg(target_arch = "x86_64")]
mod avx2_wrap {
    use llmq::precision::backend::x86;
    use llmq::precision::{CounterRng, Fp8Format};

    pub fn absmax(x: &[f32]) -> f32 {
        unsafe { x86::absmax(x) }
    }
    pub fn fp8_encode_scaled(f: Fp8Format, x: &[f32], s: f32, o: &mut [u8]) {
        unsafe { x86::fp8_encode_scaled(f, x, s, o) }
    }
    pub fn fp8_decode_scaled(f: Fp8Format, b: &[u8], s: f32, o: &mut [f32]) {
        unsafe { x86::fp8_decode_scaled(f, b, s, o) }
    }
    pub fn mx_encode_rne(x: &[f32], s: &mut [u8], c: &mut [u8]) {
        unsafe { x86::mx_encode_rne(x, s, c) }
    }
    pub fn mx_encode_sr(x: &[f32], s: &mut [u8], c: &mut [u8], r: &CounterRng, b: u32) {
        unsafe { x86::mx_encode_sr(x, s, c, r, b) }
    }
    pub fn mx_decode(s: &[u8], c: &[u8], o: &mut [f32]) {
        unsafe { x86::mx_decode(s, c, o) }
    }
}

/// The raw AVX2 kernels themselves (not just whatever dispatch picked)
/// are pinned to the scalar spec — this runs even under
/// `LLMQ_SIMD=scalar`.
#[cfg(target_arch = "x86_64")]
#[test]
fn avx2_codec_kernels_bit_identical_to_scalar_spec() {
    if !std::arch::is_x86_feature_detected!("avx2") {
        eprintln!("skipping avx2 codec pin: host CPU has no AVX2");
        return;
    }
    check_codec_matches_scalar_spec(&CodecFns {
        label: "avx2",
        absmax: avx2_wrap::absmax,
        fp8_encode_scaled: avx2_wrap::fp8_encode_scaled,
        fp8_decode_scaled: avx2_wrap::fp8_decode_scaled,
        mx_encode_rne: avx2_wrap::mx_encode_rne,
        mx_encode_sr: avx2_wrap::mx_encode_sr,
        mx_decode: avx2_wrap::mx_decode,
    });
}

/// Thin safe wrappers over the raw NEON codec kernels (baseline on
/// aarch64, so no runtime probe is needed).
#[cfg(target_arch = "aarch64")]
mod neon_wrap {
    use llmq::precision::backend::neon;
    use llmq::precision::{CounterRng, Fp8Format};

    pub fn absmax(x: &[f32]) -> f32 {
        unsafe { neon::absmax(x) }
    }
    pub fn fp8_encode_scaled(f: Fp8Format, x: &[f32], s: f32, o: &mut [u8]) {
        unsafe { neon::fp8_encode_scaled(f, x, s, o) }
    }
    pub fn fp8_decode_scaled(f: Fp8Format, b: &[u8], s: f32, o: &mut [f32]) {
        unsafe { neon::fp8_decode_scaled(f, b, s, o) }
    }
    pub fn mx_encode_rne(x: &[f32], s: &mut [u8], c: &mut [u8]) {
        unsafe { neon::mx_encode_rne(x, s, c) }
    }
    pub fn mx_encode_sr(x: &[f32], s: &mut [u8], c: &mut [u8], r: &CounterRng, b: u32) {
        unsafe { neon::mx_encode_sr(x, s, c, r, b) }
    }
    pub fn mx_decode(s: &[u8], c: &[u8], o: &mut [f32]) {
        unsafe { neon::mx_decode(s, c, o) }
    }
}

/// The raw NEON kernels are pinned to the scalar spec.
#[cfg(target_arch = "aarch64")]
#[test]
fn neon_codec_kernels_bit_identical_to_scalar_spec() {
    check_codec_matches_scalar_spec(&CodecFns {
        label: "neon",
        absmax: neon_wrap::absmax,
        fp8_encode_scaled: neon_wrap::fp8_encode_scaled,
        fp8_decode_scaled: neon_wrap::fp8_decode_scaled,
        mx_encode_rne: neon_wrap::mx_encode_rne,
        mx_encode_sr: neon_wrap::mx_encode_sr,
        mx_decode: neon_wrap::mx_decode,
    });
}

// ---------------------------------------------------------------------------
// Seeded property sweeps
// ---------------------------------------------------------------------------

/// decode(encode(x)) error is bounded by the grid's scaled ULP, and
/// rounding is idempotent (grid values are fixed points of round).
#[test]
fn prop_roundtrip_error_bounded_by_grid_ulp() {
    for (li, &n) in LENS.iter().enumerate() {
        let x = seeded(n, 0x9E37 + li as u32);

        // mx/e2m1: per block, |decode - x| <= 2 * scale. RNE error is at
        // most half the widest gap (gap 2 between 4 and 6 -> 1 * scale);
        // values saturating from just under 8*scale add at most another
        // 2 * scale - epsilon.
        let (scales, codes) = mx::encode_tensor_serial(&x);
        let mut dec = vec![0.0f32; n];
        mx::decode_tensor_serial(&scales, &codes, &mut dec);
        for (i, (&xi, &di)) in x.iter().zip(&dec).enumerate() {
            let s = mx::e8m0_decode(scales[i / MX_BLOCK]);
            assert!(
                (di - xi).abs() <= 2.0 * s,
                "mx roundtrip n={n} i={i}: {xi} -> {di} (scale {s})"
            );
        }

        // fp8: relative half-ulp bound for normals plus the denormal
        // floor; inputs stay far below either max so no saturation term.
        for fmt in [E4M3, E5M2] {
            let mut enc = vec![0u8; n];
            scalar::fp8_encode_scaled(fmt, &x, 1.0, &mut enc);
            let mut dec = vec![0.0f32; n];
            scalar::fp8_decode_scaled(fmt, &enc, 1.0, &mut dec);
            let denorm_floor = 2.0f32.powi(1 - fmt.bias - fmt.man_bits as i32);
            for (i, (&xi, &di)) in x.iter().zip(&dec).enumerate() {
                let bound = xi.abs() / 2.0f32.powi(fmt.man_bits as i32 + 1) + denorm_floor;
                assert!(
                    (di - xi).abs() <= bound,
                    "{} roundtrip n={n} i={i}: {xi} -> {di}",
                    fmt.name
                );
            }
        }

        // bf16: 8 mantissa bits -> relative error <= 2^-9 for normals.
        for &xi in &x {
            let di = llmq::precision::round_to_bf16(xi);
            assert!((di - xi).abs() <= xi.abs() * 2.0f32.powi(-8));
        }

        // idempotence: grid values are fixed points of their own round
        for &xi in x.iter().take(256) {
            for fmt in [E2M1, E4M3, E5M2] {
                let once = fmt.round(xi);
                assert_eq!(fmt.round(once).to_bits(), once.to_bits());
            }
        }
    }
}

/// Stochastic rounding is unbiased: over a counter sweep the mean of the
/// decoded SR output converges to the input, and every draw lands on one
/// of the two bracketing grid values.
#[test]
fn prop_sr_expectation_unbiased_over_counter_sweep() {
    const SWEEPS: usize = 4096;
    let rng = CounterRng::new(0x5EED);

    // mx: element 0 pins the block scale to 1.0 (absmax 6.0); element 1
    // is the probe, strictly between its hand-listed bracketing grid
    // magnitudes lo and hi.
    for (probe, lo, hi) in [
        (2.5f32, 2.0f32, 3.0f32),
        (1.25, 1.0, 1.5),
        (4.5, 4.0, 6.0),
        (-2.75, 2.0, 3.0),
    ] {
        let mut x = [0.0f32; MX_BLOCK];
        x[0] = 6.0;
        x[1] = probe;
        let mut sum = 0.0f64;
        for k in 0..SWEEPS {
            let base = (k * 64) as u32;
            let mut scales = [0u8; 1];
            let mut codes = [0u8; MX_BLOCK];
            scalar::mx_encode_sr(&x, &mut scales, &mut codes, &rng, base);
            assert_eq!(scales[0], 127, "scale pinned to 1.0");
            let mut out = [0.0f32; MX_BLOCK];
            scalar::mx_decode(&scales, &codes, &mut out);
            let q = out[1];
            assert!(
                q.abs().to_bits() == lo.to_bits() || q.abs().to_bits() == hi.to_bits(),
                "SR({probe}) left the bracketing pair: {q}"
            );
            assert_eq!(q.is_sign_negative(), probe.is_sign_negative());
            sum += q as f64;
        }
        let mean = sum / SWEEPS as f64;
        // gap-2 probes (4.5) have per-draw sd ~0.87, se ~0.014 over the
        // sweep; 0.08 is ~6 sigma, so a pass is a real unbiasedness check
        assert!(
            (mean - probe as f64).abs() < 0.08,
            "SR({probe}) biased: mean {mean}"
        );
    }

    // the same single-value property for the raw fp8 SR primitive
    for fmt in [E4M3, E5M2] {
        let probe = 1.3f32;
        let mut sum = 0.0f64;
        for k in 0..SWEEPS {
            sum += stochastic_round_fp8(fmt, probe, rng.next_u32(k as u32)) as f64;
        }
        let mean = sum / SWEEPS as f64;
        assert!(
            (mean - 1.3).abs() < 0.02,
            "{} SR(1.3) biased: mean {mean}",
            fmt.name
        );
    }

    // bf16 SR at an exact tie midpoint: mean converges to the midpoint
    let probe = f32::from_bits(0x3F80_8000); // 1 + 2^-8
    let mut sum = 0.0f64;
    for k in 0..SWEEPS {
        sum += llmq::precision::stochastic_round_bf16(probe, &rng, k as u32) as f64;
    }
    let mean = sum / SWEEPS as f64;
    assert!(
        (mean - probe as f64).abs() < 5e-4,
        "bf16 SR biased: mean {mean}"
    );
}

/// Encode is bitwise-invariant across 1/2/8 worker threads × the
/// dispatch backend (scalar or SIMD, per `LLMQ_SIMD`) × async streams on
/// or off — the parallel tensor wrappers always reproduce the
/// single-threaded pure-scalar reference exactly.
#[test]
fn prop_encode_bitwise_invariant_across_threads_simd_async() {
    let rng = CounterRng::new(0xD15B);
    for (li, &n) in LENS.iter().enumerate() {
        let x = with_specials(seeded(n, 0xF00 + li as u32));
        let base = 0x600D_u32;

        // single-threaded pure-scalar references
        let (rs, rc) = mx::encode_tensor_serial(&x);
        let (rss, rsc) = mx::encode_tensor_sr_serial(&x, &rng, base);
        let mut rdec = vec![0.0f32; n];
        mx::decode_tensor_serial(&rs, &rc, &mut rdec);
        let mut rfp8 = x.clone();
        llmq::precision::fp8::round_slice_serial(E4M3, &mut rfp8);
        let mut rbf = x.clone();
        bf16::stochastic_round_slice_serial(&mut rbf, &rng, base);

        for threads in [1usize, 2, 8] {
            for async_on in [false, true] {
                let ctx = format!("n={n} threads={threads} async={async_on}");
                par::with_threads(threads, || {
                    exec::with_async(async_on, || {
                        let (s, c) = mx::encode_tensor(&x);
                        assert_eq!(s, rs, "mx rne scales {ctx}");
                        assert_eq!(c, rc, "mx rne codes {ctx}");

                        let (s, c) = mx::encode_tensor_sr(&x, &rng, base);
                        assert_eq!(s, rss, "mx sr scales {ctx}");
                        assert_eq!(c, rsc, "mx sr codes {ctx}");

                        let mut dec = vec![0.0f32; n];
                        mx::decode_tensor(&rs, &rc, &mut dec);
                        assert_eq!(bits(&dec), bits(&rdec), "mx decode {ctx}");

                        let mut f = x.clone();
                        llmq::precision::fp8::round_slice(E4M3, &mut f);
                        assert_eq!(bits(&f), bits(&rfp8), "fp8 round {ctx}");

                        let mut b = x.clone();
                        bf16::stochastic_round_slice(&mut b, &rng, base);
                        assert_eq!(bits(&b), bits(&rbf), "bf16 sr {ctx}");
                    })
                });
            }
        }
    }
}
