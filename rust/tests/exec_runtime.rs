//! Cross-cutting pins for the `exec` async stream/event runtime
//! (NUMERICS.md Rule 4):
//!
//! * the async fused-step stream program ≡ the `LLMQ_ASYNC=off` serial
//!   oracle ≡ the synchronous fused pipeline, bitwise, across stream
//!   counts, thread counts, world sizes and clip regimes;
//! * the overlapped variant (per-chunk source-ready events driving
//!   phase-1 starts) changes nothing in the numbers;
//! * every recorded schedule replays through the DES engine with
//!   well-formed dependency edges (`sim::replay`);
//! * the double-buffer stream schedule ≡ its inline oracle;
//! * mid-run resume determinism: k steps → save → load into fresh state
//!   → k more steps ≡ 2k straight steps, async on/off, 1 and 8 threads.

use llmq::collectives::memcpy::PIPELINE_BLOCK;
use llmq::exec;
use llmq::offload::{serial_pass, stream_pass};
use llmq::optim::fused::{
    fused_step, fused_step_async, fused_step_overlapped, staged_step, HostStep,
};
use llmq::optim::{AdamWParams, MomentsMode};
use llmq::precision::{bf16, round_to_bf16, CounterRng};
use llmq::sim::{replay_trace, verify_trace, Engine};
use llmq::train::{checkpoint, StepWorkspace};
use llmq::util::par;

fn host_step(grad_clip: f32, n_micro: usize, opt_world: usize, step: u32, counter: u32) -> HostStep {
    HostStep {
        hp: AdamWParams::default(),
        lr: 3e-4,
        grad_clip,
        step,
        counter,
        seed: 9,
        n_micro,
        opt_world,
        moments: MomentsMode::Fp32,
    }
}

fn fill_dev_grads(ws: &mut StepWorkspace, salt: u32, amp: f32) {
    let n = ws.n();
    let rng = CounterRng::new(salt);
    for (d, g) in ws.dev_grads.iter_mut().enumerate() {
        for (i, x) in g.iter_mut().enumerate() {
            *x = round_to_bf16((rng.next_f32((d * n + i) as u32) - 0.5) * amp);
        }
    }
}

fn init_state(n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let p = (0..n)
        .map(|i| round_to_bf16(0.02 * (i % 101) as f32 - 1.0))
        .collect();
    let m = (0..n)
        .map(|i| round_to_bf16(0.001 * (i % 13) as f32 - 0.006))
        .collect();
    let v = (0..n).map(|i| round_to_bf16(1e-4 * (i % 7) as f32)).collect();
    (p, m, v)
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// The acceptance pin: `LLMQ_ASYNC=off` ≡ async output, across worlds,
/// stream counts, thread counts and clip regimes — referenced against
/// the staged scalar oracle so the whole tower is pinned at once.
#[test]
fn async_off_equals_async_on_matrix() {
    for (amp, clip) in [(0.05f32, 1.0f32), (4.0, 0.5)] {
        for world in [1usize, 2, 4] {
            let n = 3 * PIPELINE_BLOCK + 64; // non-block-aligned
            let hs = host_step(clip, 3 * world, 4, 2, 12_345);

            // staged scalar-kernel reference
            let mut ws = StepWorkspace::new(world, n);
            ws.begin_step();
            fill_dev_grads(&mut ws, 0xACC, amp);
            let (mut p0, mut m0, mut v0) = init_state(n);
            let norm0 =
                par::with_threads(1, || staged_step(&mut ws, &mut p0, &mut m0, &mut v0, &hs));

            for threads in [1usize, 2, 8] {
                for (async_on, streams) in [(false, 1usize), (true, 1), (true, 2), (true, 4)] {
                    let mut ws2 = StepWorkspace::new(world, n);
                    ws2.begin_step();
                    fill_dev_grads(&mut ws2, 0xACC, amp);
                    let (mut p, mut m, mut v) = init_state(n);
                    let norm = par::with_threads(threads, || {
                        exec::with_async(async_on, || {
                            exec::with_streams(streams, || {
                                fused_step_async(&mut ws2, &mut p, &mut m, &mut v, &hs)
                            })
                        })
                    });
                    let label = format!(
                        "amp={amp} world={world} t={threads} async={async_on} s={streams}"
                    );
                    assert_eq!(norm.to_bits(), norm0.to_bits(), "norm {label}");
                    assert_eq!(bits(&p), bits(&p0), "p {label}");
                    assert_eq!(bits(&m), bits(&m0), "m {label}");
                    assert_eq!(bits(&v), bits(&v0), "v {label}");
                    for r in &ws2.rank_params {
                        assert_eq!(bits(r), bits(&p), "replica {label}");
                    }
                }
            }
        }
    }
}

/// The overlapped step (accumulation streamed in, per-chunk source-ready
/// events) ≡ accumulate-first + fused, at several stream counts.
#[test]
fn overlapped_accumulation_is_unobservable() {
    let world = 2;
    let n = 2 * PIPELINE_BLOCK + 64;
    let hs = host_step(1.0, 6, 2, 3, 777);
    let rng = CounterRng::new(0xBEEF);
    // 3 microbatches per device, interleaved arrival order
    let micros: Vec<(usize, Vec<f32>)> = (0..6)
        .map(|k| {
            let dev = k % world;
            let g: Vec<f32> = (0..n)
                .map(|i| round_to_bf16((rng.next_f32((k * n + i) as u32) - 0.5) * 0.2))
                .collect();
            (dev, g)
        })
        .collect();

    let mut ws1 = StepWorkspace::new(world, n);
    ws1.begin_step();
    for (d, g) in &micros {
        bf16::accumulate_bf16(&mut ws1.dev_grads[*d], g);
    }
    let (mut p1, mut m1, mut v1) = init_state(n);
    let norm1 = fused_step(&mut ws1, &mut p1, &mut m1, &mut v1, &hs);

    for (async_on, streams) in [(false, 1usize), (true, 1), (true, 2), (true, 4)] {
        let mut ws2 = StepWorkspace::new(world, n);
        ws2.begin_step();
        let (mut p2, mut m2, mut v2) = init_state(n);
        let norm2 = exec::with_async(async_on, || {
            exec::with_streams(streams, || {
                fused_step_overlapped(&mut ws2, &mut p2, &mut m2, &mut v2, &hs, &micros)
            })
        });
        let label = format!("async={async_on} streams={streams}");
        assert_eq!(norm1.to_bits(), norm2.to_bits(), "{label}");
        assert_eq!(bits(&p1), bits(&p2), "{label}");
        assert_eq!(bits(&m1), bits(&m2), "{label}");
        assert_eq!(bits(&v1), bits(&v2), "{label}");
    }
}

/// Every schedule the consumers record passes the full static verifier
/// (`exec::verify` happens-before race detection over the ops' declared
/// access sets, plus edge-shape checks) and replays through the DES
/// engine to a finite, overlapping schedule.
#[test]
fn recorded_schedules_replay_through_des() {
    // 1) the fused step's real recorded stream program
    let n = 4 * PIPELINE_BLOCK;
    let hs = host_step(1.0, 4, 2, 2, 99);
    let mut ws = StepWorkspace::new(2, n);
    ws.begin_step();
    fill_dev_grads(&mut ws, 0xACC, 0.05);
    let (mut p, mut m, mut v) = init_state(n);
    let (_, trace) = exec::with_async(true, || {
        exec::with_streams(3, || {
            llmq::optim::fused::fused_step_async_traced(&mut ws, &mut p, &mut m, &mut v, &hs)
        })
    });
    verify_trace(&trace).expect("fused stream program is race-free");
    let mut eng = Engine::new();
    let sched = replay_trace(&mut eng, &trace).expect("well-formed fused schedule");
    assert!(sched.makespan > 0.0 && sched.makespan.is_finite());
    // per-chunk reduce + norm fold + per-chunk update = 2·chunks + 1 ops
    let launches = trace
        .ops
        .iter()
        .filter(|op| matches!(op, exec::TraceOp::Launch { .. }))
        .count();
    assert_eq!(launches, 2 * ws.n_chunks() + 1);
    // unit-cost overlap: the chunk fan-out must beat serial execution
    assert!(
        sched.makespan < launches as f64,
        "fused stream schedule shows no overlap: {} vs {launches}",
        sched.makespan
    );

    // 2) the double-buffer consumer's recorded schedule
    let mut host: Vec<Vec<f32>> = (0..6).map(|l| vec![l as f32; 32]).collect();
    let mut slots = [vec![0f32; 32], vec![0f32; 32]];
    let trace = exec::with_async(true, || {
        exec::with_streams(3, || {
            stream_pass(&mut host, &mut slots, false, true, |l, s| {
                s.iter_mut().for_each(|x| *x += l as f32)
            })
        })
    });
    verify_trace(&trace).expect("double-buffer stream program is race-free");
    let sched = replay_trace(&mut eng, &trace).expect("double-buffer schedule");
    // 6 compute ops + 6 prefetches + evictions, all at unit cost: the
    // makespan must show overlap (strictly less than the serial total).
    let total_ops = trace
        .ops
        .iter()
        .filter(|op| matches!(op, exec::TraceOp::Launch { .. }))
        .count() as f64;
    assert!(
        sched.makespan < total_ops,
        "stream schedule shows no overlap: makespan {} vs {total_ops} serial ops",
        sched.makespan
    );
}

/// The double-buffer stream schedule ≡ the inline oracle, across stream
/// counts and async modes, forward and backward, with writeback.
#[test]
fn double_buffer_stream_schedule_is_unobservable() {
    let nl = 7;
    let len = 96;
    let mk = || -> Vec<Vec<f32>> {
        (0..nl)
            .map(|l| {
                (0..len)
                    .map(|i| round_to_bf16((l * 13 + i) as f32 * 0.05 - 1.0))
                    .collect()
            })
            .collect()
    };
    let kernel = |l: usize, s: &mut [f32]| {
        for (i, x) in s.iter_mut().enumerate() {
            *x = round_to_bf16(*x * 0.75 + (l * 3 + i % 5) as f32 * 0.01);
        }
    };
    for backward in [false, true] {
        let mut h1 = mk();
        let mut s1 = [vec![0f32; len], vec![0f32; len]];
        serial_pass(&mut h1, &mut s1, backward, true, kernel);
        for (async_on, streams) in [(false, 1usize), (true, 1), (true, 3), (true, 4)] {
            let mut h2 = mk();
            let mut s2 = [vec![0f32; len], vec![0f32; len]];
            exec::with_async(async_on, || {
                exec::with_streams(streams, || {
                    stream_pass(&mut h2, &mut s2, backward, true, kernel)
                })
            });
            for l in 0..nl {
                assert_eq!(
                    bits(&h1[l]),
                    bits(&h2[l]),
                    "layer {l} bwd={backward} async={async_on} s={streams}"
                );
            }
        }
    }
}

/// Mid-run resume determinism at the host-step level (artifact-free):
/// run k steps advancing (step, counter) exactly like the Trainer, save
/// through the v2 checkpoint codec, restore into fresh buffers, run k
/// more — bitwise equal to 2k straight steps. 1 and 8 threads, async
/// on/off.
#[test]
fn resume_after_save_load_is_bitwise() {
    let world = 2;
    let n = 2 * PIPELINE_BLOCK;
    let k = 3;

    // One trainer-shaped step: fill grads (salted by step), run the
    // async fused step, advance counter by 3n like Trainer::step_impl.
    let run_steps = |p: &mut Vec<f32>,
                     m: &mut Vec<f32>,
                     v: &mut Vec<f32>,
                     step0: u32,
                     counter0: u32,
                     steps: usize|
     -> (u32, u32) {
        let mut ws = StepWorkspace::new(world, n);
        let (mut step, mut counter) = (step0, counter0);
        for _ in 0..steps {
            ws.begin_step();
            fill_dev_grads(&mut ws, 0x1000 + step, 0.08);
            step += 1;
            let hs = host_step(1.0, 4, 2, step, counter);
            fused_step_async(&mut ws, p, m, v, &hs);
            counter = counter.wrapping_add(3 * n as u32);
        }
        (step, counter)
    };

    for threads in [1usize, 8] {
        for async_on in [false, true] {
            par::with_threads(threads, || {
                exec::with_async(async_on, || {
                    // straight 2k
                    let (mut p0, mut m0, mut v0) = init_state(n);
                    run_steps(&mut p0, &mut m0, &mut v0, 0, 1, 2 * k);

                    // k, save, load into fresh state, k more
                    let (mut p1, mut m1, mut v1) = init_state(n);
                    let (step, counter) = run_steps(&mut p1, &mut m1, &mut v1, 0, 1, k);
                    let blob = checkpoint::encode(step, counter, 1, &p1, &m1, &v1);

                    let (mut p2, mut m2, mut v2) =
                        (vec![0f32; n], vec![0f32; n], vec![0f32; n]);
                    let (step2, counter2) =
                        checkpoint::decode_into(&blob, &mut p2, &mut m2, &mut v2).unwrap();
                    assert_eq!((step2, counter2), (step, counter));
                    run_steps(&mut p2, &mut m2, &mut v2, step2, counter2, k);

                    let label = format!("t={threads} async={async_on}");
                    assert_eq!(bits(&p0), bits(&p2), "p {label}");
                    assert_eq!(bits(&m0), bits(&m2), "m {label}");
                    assert_eq!(bits(&v0), bits(&v2), "v {label}");
                })
            });
        }
    }
}
