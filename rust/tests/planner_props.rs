//! Property tests over the memory planner and the auto-planner: footprint
//! monotonicity, fit-implies-resources invariants, and the paper's
//! qualitative relationships across random configurations.

use llmq::config::paper_presets;
use llmq::hw::gpu_by_name;
use llmq::memory::{plan, PlanInput};
use llmq::optim::MomentsMode;
use llmq::offload::OffloadConfig;
use llmq::recompute::Recompute;
use llmq::shard::ShardConfig;
use llmq::util::prop;

fn random_offload(g: &mut prop::Gen) -> OffloadConfig {
    OffloadConfig {
        residuals: g.bool(),
        moments: g.bool(),
        master: g.bool(),
        params: g.bool(),
        grads: g.bool(),
        zero_copy: false,
    }
}

fn random_recompute(g: &mut prop::Gen) -> Recompute {
    Recompute::ALL[g.usize_in(0, Recompute::ALL.len() - 1)]
}

#[test]
fn prop_offloading_never_increases_device_bytes() {
    let gpus = ["RTX 5060Ti", "RTX 4090", "L40S"];
    let models = paper_presets();
    prop::check(0x11, 120, |g| {
        let gpu = gpu_by_name(gpus[g.usize_in(0, 2)]).unwrap();
        let m = &models[g.usize_in(0, models.len() - 1)];
        let rc = random_recompute(g);
        let off = random_offload(g);
        let b = g.usize_in(1, 16);
        let fp8 = g.bool();
        let base = PlanInput {
            model: m,
            gpu: &gpu,
            fp8,
            moments: MomentsMode::Fp32,
            recompute: rc,
            offload: OffloadConfig::NONE,
            shard: ShardConfig::single(),
            micro_batch: b,
        };
        let with = PlanInput {
            offload: off,
            ..base.clone()
        };
        let p0 = plan(&base, 256.0);
        let p1 = plan(&with, 256.0);
        assert!(
            p1.dev_total <= p0.dev_total + 1.0,
            "offload increased device bytes: {} -> {}",
            p0.dev_total,
            p1.dev_total
        );
        // and whatever left the device must appear on the host
        if off.any() {
            assert!(p1.host_bytes > 0.0);
        }
    });
}

#[test]
fn prop_quantized_moments_never_increase_any_budget() {
    // The precision axis is monotone: fp8/bf16 moment storage can only
    // shrink the device and host ledgers, and it touches nothing but
    // the moments class.
    let gpus = ["RTX 5060Ti", "RTX 4090", "L40S"];
    let models = paper_presets();
    prop::check(0x66, 120, |g| {
        let gpu = gpu_by_name(gpus[g.usize_in(0, 2)]).unwrap();
        let m = &models[g.usize_in(0, models.len() - 1)];
        let base = PlanInput {
            model: m,
            gpu: &gpu,
            fp8: g.bool(),
            moments: MomentsMode::Fp32,
            recompute: random_recompute(g),
            offload: random_offload(g),
            shard: ShardConfig::single(),
            micro_batch: g.usize_in(1, 16),
        };
        let q = PlanInput {
            moments: MomentsMode::Fp8,
            ..base.clone()
        };
        let p32 = plan(&base, 256.0);
        let p8 = plan(&q, 256.0);
        assert!(p8.dev_total <= p32.dev_total);
        assert!(p8.host_bytes <= p32.host_bytes);
        assert!(p8.dev_moments <= p32.dev_moments);
        assert_eq!(p8.dev_weights, p32.dev_weights);
        assert_eq!(p8.dev_master, p32.dev_master);
        assert_eq!(p8.dev_grads, p32.dev_grads);
        assert_eq!(p8.dev_activations, p32.dev_activations);
        assert_eq!(p8.dev_residuals, p32.dev_residuals);
        assert_eq!(p8.dev_workspace, p32.dev_workspace);
    });
}

#[test]
fn prop_more_recompute_less_activation_memory() {
    let models = paper_presets();
    prop::check(0x22, 80, |g| {
        let gpu = gpu_by_name("RTX 4090").unwrap();
        let m = &models[g.usize_in(0, models.len() - 1)];
        let b = g.usize_in(1, 8);
        let fp8 = false; // fp8 adds transpose buffers (tested separately)
        let mut prev = f64::INFINITY;
        for rc in Recompute::ALL {
            let p = plan(
                &PlanInput {
                    model: m,
                    gpu: &gpu,
                    fp8,
                    moments: MomentsMode::Fp32,
                    recompute: rc,
                    offload: OffloadConfig::NONE,
                    shard: ShardConfig::single(),
                    micro_batch: b,
                },
                256.0,
            );
            assert!(
                p.dev_activations <= prev + 1.0,
                "{rc:?} grew activations"
            );
            prev = p.dev_activations;
        }
    });
}

#[test]
fn prop_sharding_reduces_per_device_state() {
    let models = paper_presets();
    prop::check(0x33, 80, |g| {
        let gpu = gpu_by_name("RTX 4090").unwrap();
        let m = &models[g.usize_in(0, models.len() - 1)];
        let b = g.usize_in(1, 4);
        let mk = |shard: ShardConfig| {
            plan(
                &PlanInput {
                    model: m,
                    gpu: &gpu,
                    fp8: true,
                    moments: MomentsMode::Fp32,
                    recompute: Recompute::Block,
                    offload: OffloadConfig::NONE,
                    shard,
                    micro_batch: b,
                },
                256.0,
            )
        };
        let single = mk(ShardConfig::single());
        let z1 = mk(ShardConfig::zero1(4));
        let full = mk(ShardConfig::full(4));
        assert!(z1.dev_moments < single.dev_moments);
        assert!(full.dev_total < z1.dev_total + 1.0);
    });
}

#[test]
fn prop_autoplan_result_always_fits() {
    let models = paper_presets();
    prop::check(0x44, 12, |g| {
        let gpus = ["RTX 5060Ti", "RTX 4090", "L40S"];
        let gpu = gpu_by_name(gpus[g.usize_in(0, 2)]).unwrap();
        let m = &models[g.usize_in(0, 3)]; // 0.5B..7B keep runtime bounded
        let world = [1usize, 4][g.usize_in(0, 1)];
        if let Ok((cfg, r)) = llmq::coordinator::autoplan(
            m,
            &gpu,
            world,
            g.bool(),
            500_000,
            llmq::sim::CommBackend::MemcpyFull,
            0,
        ) {
            assert!(cfg.plan.fits, "autoplan returned non-fitting config");
            assert!(cfg.plan.host_fits);
            assert!(r.tokens_per_s > 0.0 && r.mfu > 0.0 && r.mfu < 1.0);
        }
    });
}

#[test]
fn prop_mfu_bounded() {
    // Simulated MFU must stay in (0, 1) for every fitting random config.
    let models = paper_presets();
    prop::check(0x55, 40, |g| {
        let gpu = gpu_by_name("RTX 4090").unwrap();
        let m = &models[g.usize_in(0, 2)];
        let node = llmq::hw::NodeTopology::new(gpu.clone(), 1);
        let cfg = llmq::sim::StepConfig {
            micro_batch: g.usize_in(1, 16),
            grad_accum: g.usize_in(1, 8),
            recompute: random_recompute(g),
            offload: random_offload(g),
            shard: ShardConfig::single(),
            comm: llmq::sim::CommBackend::MemcpyFull,
            transfer_mode: llmq::offload::TransferMode::DoubleBuffer,
        };
        let r = llmq::sim::simulate_step(m, &node, g.bool(), &cfg);
        assert!(r.mfu > 0.0 && r.mfu < 1.0, "mfu {}", r.mfu);
        assert!(r.step_s.is_finite() && r.step_s > 0.0);
    });
}
