//! Property tests over the collectives (proptest-style, using the
//! deterministic in-repo generator): both reduce-scatter implementations
//! agree with each other and with the dense reference across random
//! world sizes, lengths and values; all-gathers are exact and identical.

use llmq::collectives::{
    all_gather_memcpy, all_gather_ring, allreduce_reference,
    reduce_scatter_memcpy, reduce_scatter_ring, DeviceGroup,
};
use llmq::precision::{round_to_bf16, CounterRng};
use llmq::util::prop;

fn random_group(g: &mut prop::Gen) -> DeviceGroup {
    let world = g.usize_in(2, 6);
    let chunk = g.usize_in(1, 64);
    let n = world * chunk;
    let vals: Vec<Vec<f32>> = (0..world)
        .map(|_| {
            (0..n)
                .map(|_| round_to_bf16(g.f32_in(-4.0, 4.0)))
                .collect()
        })
        .collect();
    DeviceGroup {
        world,
        buffers: vals,
    }
}

#[test]
fn prop_memcpy_rs_matches_reference() {
    prop::check(0xA11CE, 60, |g| {
        let grp = random_group(g);
        let world = grp.world;
        let chunk = grp.chunk_len();
        let reference = allreduce_reference(&grp);
        let mut acc = vec![vec![0f32; chunk]; world];
        reduce_scatter_memcpy(&grp, &mut acc, &CounterRng::new(5), 0);
        for w in 0..world {
            for i in 0..chunk {
                let exact = reference[w * chunk + i];
                let err = (acc[w][i] - exact).abs();
                // SR picks one of the bracketing bf16 neighbours
                let ulp = exact.abs().max(1e-3) / 128.0;
                assert!(err <= ulp, "w{w} i{i}: {} vs {exact}", acc[w][i]);
            }
        }
    });
}

#[test]
fn prop_ring_and_memcpy_rs_agree() {
    // Same reduction contract: both within one bf16 SR ulp of the
    // reference, hence within 2 ulp of each other.
    prop::check(0xB0B, 40, |g| {
        let grp = random_group(g);
        let world = grp.world;
        let chunk = grp.chunk_len();
        let mut a = vec![vec![0f32; chunk]; world];
        let mut b = vec![vec![0f32; chunk]; world];
        reduce_scatter_memcpy(&grp, &mut a, &CounterRng::new(9), 7);
        reduce_scatter_ring(&grp, &mut b, &CounterRng::new(9), 7);
        for w in 0..world {
            for i in 0..chunk {
                let err = (a[w][i] - b[w][i]).abs();
                let ulp = a[w][i].abs().max(1e-3) / 64.0;
                assert!(err <= ulp, "w{w} i{i}: {} vs {}", a[w][i], b[w][i]);
            }
        }
    });
}

#[test]
fn prop_all_gathers_identical_and_exact() {
    prop::check(0xC0FFEE, 60, |g| {
        let world = g.usize_in(2, 6);
        let chunk = g.usize_in(1, 48);
        let shards: Vec<Vec<f32>> = (0..world)
            .map(|_| g.vec_f32(chunk, -100.0, 100.0))
            .collect();
        let mut a = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
        let mut b = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
        all_gather_memcpy(&shards, &mut a);
        all_gather_ring(&shards, &mut b);
        assert_eq!(a.buffers, b.buffers);
        // every rank has the concatenation of all shards, bit-exact
        for w in 0..world {
            for (src, sh) in shards.iter().enumerate() {
                assert_eq!(&a.buffers[w][src * chunk..(src + 1) * chunk], &sh[..]);
            }
        }
    });
}

#[test]
fn prop_rs_deterministic_under_repeat() {
    prop::check(0xDE7, 30, |g| {
        let grp = random_group(g);
        let run = |grp: &DeviceGroup| {
            let mut acc = vec![vec![0.5f32; grp.chunk_len()]; grp.world];
            reduce_scatter_memcpy(grp, &mut acc, &CounterRng::new(3), 42);
            acc
        };
        assert_eq!(run(&grp), run(&grp));
    });
}

#[test]
fn prop_gather_then_scatter_roundtrip() {
    // all-gather shards, reduce-scatter the gathered copies: each rank
    // ends with world × its shard (every rank contributed an identical
    // full buffer).
    prop::check(0x600D, 30, |g| {
        let world = g.usize_in(2, 4);
        let chunk = g.usize_in(1, 32);
        let shards: Vec<Vec<f32>> = (0..world)
            .map(|_| {
                (0..chunk)
                    .map(|_| round_to_bf16(g.f32_in(-1.0, 1.0)))
                    .collect()
            })
            .collect();
        let mut gathered = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
        all_gather_memcpy(&shards, &mut gathered);
        let mut acc = vec![vec![0f32; chunk]; world];
        reduce_scatter_memcpy(&gathered, &mut acc, &CounterRng::new(1), 0);
        for w in 0..world {
            for i in 0..chunk {
                let exact = shards[w][i] * world as f32;
                let err = (acc[w][i] - exact).abs();
                assert!(
                    err <= exact.abs().max(1e-2) / 64.0,
                    "{} vs {exact}",
                    acc[w][i]
                );
            }
        }
    });
}
