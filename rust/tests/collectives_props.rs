//! Property tests over the collectives (proptest-style, using the
//! deterministic in-repo generator): both reduce-scatter implementations
//! agree with each other and with the dense reference across random
//! world sizes, lengths and values; all-gathers are exact and identical.

use llmq::collectives::{
    all_gather_memcpy, all_gather_ring, allreduce_reference,
    reduce_scatter_memcpy, reduce_scatter_ring, DeviceGroup,
};
use llmq::precision::{round_to_bf16, CounterRng};
use llmq::util::prop;

fn random_group(g: &mut prop::Gen) -> DeviceGroup {
    let world = g.usize_in(2, 6);
    let chunk = g.usize_in(1, 64);
    let n = world * chunk;
    let vals: Vec<Vec<f32>> = (0..world)
        .map(|_| {
            (0..n)
                .map(|_| round_to_bf16(g.f32_in(-4.0, 4.0)))
                .collect()
        })
        .collect();
    DeviceGroup {
        world,
        buffers: vals,
    }
}

#[test]
fn prop_memcpy_rs_matches_reference() {
    prop::check(0xA11CE, 60, |g| {
        let grp = random_group(g);
        let world = grp.world;
        let chunk = grp.chunk_len();
        let reference = allreduce_reference(&grp);
        let mut acc = vec![vec![0f32; chunk]; world];
        reduce_scatter_memcpy(&grp, &mut acc, &CounterRng::new(5), 0);
        for w in 0..world {
            for i in 0..chunk {
                let exact = reference[w * chunk + i];
                let err = (acc[w][i] - exact).abs();
                // SR picks one of the bracketing bf16 neighbours
                let ulp = exact.abs().max(1e-3) / 128.0;
                assert!(err <= ulp, "w{w} i{i}: {} vs {exact}", acc[w][i]);
            }
        }
    });
}

#[test]
fn prop_ring_and_memcpy_rs_agree() {
    // One reduction contract (ascending-src sum + element-index-keyed
    // SR): the two backends are bit-identical, not merely ULP-close.
    prop::check(0xB0B, 40, |g| {
        let grp = random_group(g);
        let world = grp.world;
        let chunk = grp.chunk_len();
        let mut a = vec![vec![0f32; chunk]; world];
        let mut b = vec![vec![0f32; chunk]; world];
        reduce_scatter_memcpy(&grp, &mut a, &CounterRng::new(9), 7);
        reduce_scatter_ring(&grp, &mut b, &CounterRng::new(9), 7);
        for w in 0..world {
            for i in 0..chunk {
                assert_eq!(
                    a[w][i].to_bits(),
                    b[w][i].to_bits(),
                    "w{w} i{i}: {} vs {}",
                    a[w][i],
                    b[w][i]
                );
            }
        }
    });
}

/// The ascending-src reduction-order contract, pinned for both backends
/// by an independent re-derivation: world ∈ {1, 2, 4}, unaligned n (not
/// a multiple of the pipeline block), non-zero accumulators, counter
/// offsets, and 1/2/8 worker threads on the memcpy side.
#[test]
fn ring_memcpy_bit_identity_sweep() {
    use llmq::collectives::memcpy::PIPELINE_BLOCK;
    use llmq::precision::bf16::stochastic_round_bf16;

    for world in [1usize, 2, 4] {
        // unaligned: chunks are odd and not pipeline-block multiples
        for chunk in [1usize, 37, PIPELINE_BLOCK + 129] {
            let n = world * chunk;
            let rng_data = CounterRng::new(0x5EED);
            let grp = DeviceGroup::from_fn(world, n, |r, i| {
                round_to_bf16((rng_data.next_f32((r * n + i) as u32) - 0.5) * 2.0)
            });
            for counter in [0u32, 1_000_003] {
                let sr = CounterRng::new(0x0D0);
                // independent re-derivation of the contract: ascending
                // src fold seeded with the accumulator, one SR draw at
                // counter + global index
                let mut want = vec![vec![0.25f32; chunk]; world];
                for (w, acc) in want.iter_mut().enumerate() {
                    for (i, a) in acc.iter_mut().enumerate() {
                        let mut sum = *a;
                        for src in 0..world {
                            sum += grp.buffers[src][w * chunk + i];
                        }
                        *a = stochastic_round_bf16(
                            sum,
                            &sr,
                            counter.wrapping_add((w * chunk + i) as u32),
                        );
                    }
                }

                let mut ring = vec![vec![0.25f32; chunk]; world];
                reduce_scatter_ring(&grp, &mut ring, &sr, counter);
                assert_eq!(ring, want, "ring world={world} chunk={chunk}");

                for threads in [1usize, 2, 8] {
                    let mut mc = vec![vec![0.25f32; chunk]; world];
                    llmq::util::par::with_threads(threads, || {
                        reduce_scatter_memcpy(&grp, &mut mc, &sr, counter)
                    });
                    assert_eq!(mc, want, "memcpy world={world} chunk={chunk} t={threads}");
                }
            }
        }
    }
}

/// All-gather parity at the same sweep geometry: pure copies, bit-exact
/// and identical between backends.
#[test]
fn all_gather_ring_memcpy_bit_identity_sweep() {
    for world in [1usize, 2, 4] {
        for chunk in [1usize, 37, 1000] {
            let shards: Vec<Vec<f32>> = (0..world)
                .map(|r| {
                    (0..chunk)
                        .map(|i| round_to_bf16((r * 31 + i) as f32 * 0.17 - 3.0))
                        .collect()
                })
                .collect();
            let mut a = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
            let mut b = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
            all_gather_memcpy(&shards, &mut a);
            all_gather_ring(&shards, &mut b);
            assert_eq!(a.buffers, b.buffers, "world={world} chunk={chunk}");
        }
    }
}

#[test]
fn prop_all_gathers_identical_and_exact() {
    prop::check(0xC0FFEE, 60, |g| {
        let world = g.usize_in(2, 6);
        let chunk = g.usize_in(1, 48);
        let shards: Vec<Vec<f32>> = (0..world)
            .map(|_| g.vec_f32(chunk, -100.0, 100.0))
            .collect();
        let mut a = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
        let mut b = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
        all_gather_memcpy(&shards, &mut a);
        all_gather_ring(&shards, &mut b);
        assert_eq!(a.buffers, b.buffers);
        // every rank has the concatenation of all shards, bit-exact
        for w in 0..world {
            for (src, sh) in shards.iter().enumerate() {
                assert_eq!(&a.buffers[w][src * chunk..(src + 1) * chunk], &sh[..]);
            }
        }
    });
}

#[test]
fn prop_rs_deterministic_under_repeat() {
    prop::check(0xDE7, 30, |g| {
        let grp = random_group(g);
        let run = |grp: &DeviceGroup| {
            let mut acc = vec![vec![0.5f32; grp.chunk_len()]; grp.world];
            reduce_scatter_memcpy(grp, &mut acc, &CounterRng::new(3), 42);
            acc
        };
        assert_eq!(run(&grp), run(&grp));
    });
}

#[test]
fn prop_gather_then_scatter_roundtrip() {
    // all-gather shards, reduce-scatter the gathered copies: each rank
    // ends with world × its shard (every rank contributed an identical
    // full buffer).
    prop::check(0x600D, 30, |g| {
        let world = g.usize_in(2, 4);
        let chunk = g.usize_in(1, 32);
        let shards: Vec<Vec<f32>> = (0..world)
            .map(|_| {
                (0..chunk)
                    .map(|_| round_to_bf16(g.f32_in(-1.0, 1.0)))
                    .collect()
            })
            .collect();
        let mut gathered = DeviceGroup::from_fn(world, world * chunk, |_, _| 0.0);
        all_gather_memcpy(&shards, &mut gathered);
        let mut acc = vec![vec![0f32; chunk]; world];
        reduce_scatter_memcpy(&gathered, &mut acc, &CounterRng::new(1), 0);
        for w in 0..world {
            for i in 0..chunk {
                let exact = shards[w][i] * world as f32;
                let err = (acc[w][i] - exact).abs();
                assert!(
                    err <= exact.abs().max(1e-2) / 64.0,
                    "{} vs {exact}",
                    acc[w][i]
                );
            }
        }
    });
}
