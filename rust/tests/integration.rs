//! Cross-module integration tests: trainer end-to-end on the tiny preset,
//! checkpoint round-trips, python↔rust numeric parity fixtures, and the
//! determinism guarantees the paper claims (§3 "Reproducibility").

use llmq::config::{Dtype, TrainConfig};
use llmq::data::{ByteTokenizer, PackedDataset};
use llmq::precision::{round_to_bf16, CounterRng, E4M3};
use llmq::train::Trainer;

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/tiny_manifest.json").exists()
}

fn tiny_cfg(dtype: Dtype, world: usize) -> TrainConfig {
    TrainConfig {
        dtype,
        grad_accum: 2,
        steps: 3,
        lr: 1e-3,
        seed: 7,
        world,
        eval_every: 0,
        ..Default::default()
    }
}

fn corpus() -> String {
    llmq::data::SynthCorpus::new(1).text(0, 40_000)
}

#[test]
fn trainer_reduces_loss_on_tiny() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut t = Trainer::new(
        "artifacts",
        "tiny",
        TrainConfig {
            steps: 12,
            ..tiny_cfg(Dtype::Fp8, 1)
        },
    )
    .unwrap();
    let stats = t.train_loop(&corpus(), 12, |_| {}).unwrap();
    let first = stats[0].loss;
    let last = stats.last().unwrap().loss;
    assert!(last < first, "loss should drop: {first} -> {last}");
    assert!(stats.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn training_is_bitwise_deterministic() {
    if !have_artifacts() {
        return;
    }
    let run = || {
        let mut t = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Fp8, 1)).unwrap();
        t.train_loop(&corpus(), 3, |_| {}).unwrap();
        (t.params.clone(), t.m.clone(), t.v.clone())
    };
    let (p1, m1, v1) = run();
    let (p2, m2, v2) = run();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&p1), bits(&p2), "params bitwise equal (paper §3)");
    assert_eq!(bits(&m1), bits(&m2));
    assert_eq!(bits(&v1), bits(&v2));
}

#[test]
fn world4_training_runs_and_state_stays_bf16() {
    if !have_artifacts() {
        return;
    }
    let mut t = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Bf16, 4)).unwrap();
    let stats = t.train_loop(&corpus(), 2, |_| {}).unwrap();
    assert_eq!(stats.len(), 2);
    for &x in t.params.iter().chain(&t.m).chain(&t.v) {
        assert_eq!(x, round_to_bf16(x), "state on bf16 grid");
    }
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("llmq_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.bin");
    let text = corpus();

    let mut a = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Fp8, 1)).unwrap();
    a.train_loop(&text, 2, |_| {}).unwrap();
    a.save_checkpoint(path.to_str().unwrap()).unwrap();
    let after_save_step = a.step;

    let mut b = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Fp8, 1)).unwrap();
    b.load_checkpoint(path.to_str().unwrap()).unwrap();
    assert_eq!(b.step, after_save_step);
    assert_eq!(
        a.params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        b.params.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
    assert_eq!(a.counter, b.counter);
}

/// Mid-run resume determinism at the trainer level: train k steps →
/// save → load into a *fresh* Trainer → train k more ≡ 2k straight
/// steps, bitwise (params, moments, counter). The host-level artifact-
/// free version (threads × async sweep) lives in tests/exec_runtime.rs.
#[test]
fn resume_mid_run_matches_straight_run() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("llmq_resume_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.bin");
    let text = corpus();
    let k = 2;

    let mut straight = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Fp8, 1)).unwrap();
    straight.train_loop(&text, 2 * k, |_| {}).unwrap();

    let mut a = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Fp8, 1)).unwrap();
    a.train_loop(&text, k, |_| {}).unwrap();
    a.save_checkpoint(path.to_str().unwrap()).unwrap();

    let mut b = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Fp8, 1)).unwrap();
    b.load_checkpoint(path.to_str().unwrap()).unwrap();
    // The loop re-derives batches from the step index, so resuming
    // replays exactly the straight run's second half.
    let per_step = b.cfg.grad_accum * b.cfg.world;
    let tok = ByteTokenizer::new(b.man.config.vocab);
    let ds = PackedDataset::from_text(&text, &tok, b.man.config.seq_len, b.cfg.seed);
    for s in k..2 * k {
        let batches: Vec<_> = (0..per_step)
            .map(|i| ds.batch(s * per_step + i, i % b.cfg.world, b.man.batch))
            .collect();
        b.train_step(&batches).unwrap();
    }

    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(straight.step, b.step);
    assert_eq!(straight.counter, b.counter);
    assert_eq!(bits(&straight.params), bits(&b.params));
    assert_eq!(bits(&straight.m), bits(&b.m));
    assert_eq!(bits(&straight.v), bits(&b.v));
}

/// Quantized-moments resume: under `--moments fp8` the save routes to
/// the v4 wire format (7 bytes/param instead of 12), and because the
/// resident m/v already live on the e5m2/bf16 grids the codec is
/// lossless — save → load into a fresh Trainer → k more steps is
/// bitwise identical to 2k straight steps, exactly like the f32 case.
#[test]
fn quantized_moments_resume_matches_straight_run() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("llmq_resume_q_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid_q.bin");
    let text = corpus();
    let k = 2;
    let cfg = || TrainConfig {
        moments: llmq::optim::MomentsMode::Fp8,
        ..tiny_cfg(Dtype::Fp8, 1)
    };

    let mut straight = Trainer::new("artifacts", "tiny", cfg()).unwrap();
    straight.train_loop(&text, 2 * k, |_| {}).unwrap();

    let mut a = Trainer::new("artifacts", "tiny", cfg()).unwrap();
    a.train_loop(&text, k, |_| {}).unwrap();
    a.save_checkpoint(path.to_str().unwrap()).unwrap();

    // the file on disk really is the 7-byte/param v4 format
    let bytes = std::fs::read(&path).unwrap();
    let info = llmq::train::checkpoint::inspect(&bytes).unwrap();
    assert_eq!(info.version, llmq::train::checkpoint::VERSION_Q);
    assert_eq!(bytes.len(), 36 + 7 * a.params.len());

    let mut b = Trainer::new("artifacts", "tiny", cfg()).unwrap();
    b.load_checkpoint(path.to_str().unwrap()).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a.m), bits(&b.m), "v4 moment codec is lossless");
    assert_eq!(bits(&a.v), bits(&b.v));
    let per_step = b.cfg.grad_accum * b.cfg.world;
    let tok = ByteTokenizer::new(b.man.config.vocab);
    let ds = PackedDataset::from_text(&text, &tok, b.man.config.seq_len, b.cfg.seed);
    for s in k..2 * k {
        let batches: Vec<_> = (0..per_step)
            .map(|i| ds.batch(s * per_step + i, i % b.cfg.world, b.man.batch))
            .collect();
        b.train_step(&batches).unwrap();
    }

    assert_eq!(straight.step, b.step);
    assert_eq!(straight.counter, b.counter);
    assert_eq!(bits(&straight.params), bits(&b.params));
    assert_eq!(bits(&straight.m), bits(&b.m));
    assert_eq!(bits(&straight.v), bits(&b.v));
}

/// Foreign and pre-header checkpoint files are rejected by name instead
/// of being misread as state (the v2 header hardening).
#[test]
fn foreign_checkpoint_file_is_rejected() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("llmq_ckpt_reject_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("foreign.bin");
    let mut t = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Fp8, 1)).unwrap();
    // a v1-shaped blob of exactly the legacy-accepted length
    let n = t.params.len();
    let mut blob = vec![0u8; 16 + 12 * n];
    blob[8..16].copy_from_slice(&(n as u64).to_le_bytes());
    std::fs::write(&path, &blob).unwrap();
    let err = t.load_checkpoint(path.to_str().unwrap()).unwrap_err();
    assert!(
        err.to_string().contains("not an LLMQ checkpoint"),
        "named rejection, got: {err}"
    );
}

#[test]
fn val_loss_close_to_train_loss_at_init() {
    if !have_artifacts() {
        return;
    }
    let mut t = Trainer::new("artifacts", "tiny", tiny_cfg(Dtype::Bf16, 1)).unwrap();
    let tok = ByteTokenizer::new(t.man.config.vocab);
    let ds = PackedDataset::from_text(&corpus(), &tok, t.man.config.seq_len, 0);
    let vb: Vec<_> = (0..2).map(|i| ds.val_batch(i, t.man.batch)).collect();
    let vl = t.val_loss(&vb).unwrap();
    // Untrained model on ~uniform byte data: CE near ln(vocab).
    let expect = (t.man.config.vocab as f32).ln();
    assert!((vl - expect).abs() < 0.8, "val {vl} vs ln(V) {expect}");
}

#[test]
fn precision_policies_agree_at_init() {
    if !have_artifacts() {
        return;
    }
    // The three policies share initial params; their first-step losses
    // must agree closely (quantization noise only).
    let text = corpus();
    let mut losses = vec![];
    for dtype in [Dtype::Bf16, Dtype::Fp8, Dtype::Fp8E5m2] {
        let mut t = Trainer::new("artifacts", "tiny", tiny_cfg(dtype, 1)).unwrap();
        let stats = t.train_loop(&text, 1, |_| {}).unwrap();
        losses.push(stats[0].loss);
    }
    for w in losses.windows(2) {
        assert!(
            (w[0] - w[1]).abs() < 0.05,
            "policy losses diverge at init: {losses:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// python ↔ rust parity fixtures (generated from compile.kernels.ref).
// ---------------------------------------------------------------------------

#[test]
fn fp8_codec_parity_fixture() {
    // ref.round_to_fp8([0.3, -7.7, 300.0, 1e-5], E4M3)
    //   == [0.3125, -7.5, 288.0, 0.0]
    let inputs = [0.3f32, -7.7, 300.0, 1e-5];
    let expect = [0.3125f32, -7.5, 288.0, 0.0];
    for (x, e) in inputs.iter().zip(expect) {
        assert_eq!(E4M3.round(*x), e, "x={x}");
    }
}

#[test]
fn counter_rng_stream_disjointness() {
    // Trainer advances counter by 3·padded per step; SR draws must never
    // collide within a step across elements.
    let rng = CounterRng::new(0x11A17);
    let n = 1024u32;
    let mut seen = std::collections::HashSet::new();
    for base in [1u32, 1 + 3 * n] {
        for i in 0..n {
            assert!(seen.insert(rng.next_u32(base + i)), "collision at {i}");
        }
    }
}
