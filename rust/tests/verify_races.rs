//! Negative-space suite for the static race detector (`exec::verify`):
//! seeded mutants of the *real* fused-step stream program must each be
//! rejected with a named, range-carrying error.
//!
//! Geometry: world 2, 4 streams, 2 chunks — every chunk gets its own
//! worker stream (`work_stream(c) = (world + c) % ns` → streams 2 and
//! 3), so no FIFO edge or sibling-chunk event masks a dropped wait and
//! each cross-stream `Wait` is individually load-bearing. The recorded
//! program is:
//!
//! ```text
//! L(s2 reduce+partials) R(s2 e0)   L(s3 reduce+partials) R(s3 e1)
//! W(s0 e0) W(s0 e1)  L(s0 norm-fold)  R(s0 e2)
//! W(s0 e2) W(s1 e2) W(s2 e2) W(s3 e2)
//! L(s2 update+gather)  L(s3 update+gather)
//! ```
//!
//! The sweep drops each `Wait` in turn: the four load-bearing edges
//! must produce a race naming the op labels and the exact overlapping
//! byte range; the two benign drops (the fold stream's FIFO-redundant
//! self-wait and idle stream 1's barrier wait) must stay clean — the
//! detector is exact, not conservative, on this program. Hand-built
//! mutants cover the remaining error classes: write-write overlap,
//! wait-before-record, and a reused (one-shot) event.

use llmq::collectives::memcpy::PIPELINE_BLOCK;
use llmq::exec::{self, verify, AccessSet, Trace, TraceOp};
use llmq::optim::fused::{fused_step_async_traced, HostStep};
use llmq::optim::{AdamWParams, MomentsMode};
use llmq::precision::{round_to_bf16, CounterRng};
use llmq::train::StepWorkspace;

const WORLD: usize = 2;
const STREAMS: usize = 4;
const N: usize = 2 * PIPELINE_BLOCK;

/// Record the fused optimizer step's stream program at the pinned
/// geometry (and let the `LLMQ_VERIFY` scope hook see it live).
fn record_fused_trace() -> Trace {
    let hs = HostStep {
        hp: AdamWParams::default(),
        lr: 3e-4,
        grad_clip: 1.0,
        step: 2,
        counter: 12_345,
        seed: 9,
        n_micro: 2 * WORLD,
        opt_world: 2,
        moments: MomentsMode::Fp32,
    };
    let mut ws = StepWorkspace::new(WORLD, N);
    ws.begin_step();
    let rng = CounterRng::new(0xACC);
    for (d, g) in ws.dev_grads.iter_mut().enumerate() {
        for (i, x) in g.iter_mut().enumerate() {
            *x = round_to_bf16((rng.next_f32((d * N + i) as u32) - 0.5) * 0.05);
        }
    }
    let mut p: Vec<f32> = (0..N).map(|i| round_to_bf16(0.02 * (i % 101) as f32 - 1.0)).collect();
    let mut m = vec![0f32; N];
    let mut v = vec![0f32; N];
    let (_, trace) = exec::with_async(true, || {
        exec::with_verify(true, || {
            exec::with_streams(STREAMS, || {
                fused_step_async_traced(&mut ws, &mut p, &mut m, &mut v, &hs)
            })
        })
    });
    trace
}

fn wait_indices(trace: &Trace) -> Vec<usize> {
    trace
        .ops
        .iter()
        .enumerate()
        .filter(|(_, op)| matches!(op, TraceOp::Wait { .. }))
        .map(|(i, _)| i)
        .collect()
}

fn drop_op(trace: &Trace, idx: usize) -> Trace {
    let mut ops = trace.ops.clone();
    ops.remove(idx);
    Trace {
        n_streams: trace.n_streams,
        async_mode: trace.async_mode,
        ops,
    }
}

/// Every seeded missing-edge mutant of the fused-step program is
/// flagged by op label and overlapping byte range; the two provably
/// redundant edges stay clean when dropped.
#[test]
fn dropped_wait_mutants_are_flagged_by_label_and_range() {
    let trace = record_fused_trace();
    verify::check(&trace).expect("unmutated program must verify clean");

    let waits = wait_indices(&trace);
    assert_eq!(
        waits.len(),
        2 + STREAMS,
        "geometry drifted: expected per-chunk fold waits + per-stream barrier waits"
    );

    // In submission order: W(s0,e0) W(s0,e1) W(s0,e2) W(s1,e2) W(s2,e2)
    // W(s3,e2). `None` = dropping the edge is benign (FIFO-redundant or
    // the stream runs nothing afterwards); `Some((label, arena, range))`
    // = the race the detector must report, with the exact byte overlap.
    let norm_lanes_bytes = 8 * llmq::precision::backend::NORM_LANES as u64;
    let chunk0 = format!("bytes 0..{norm_lanes_bytes}");
    let chunk1 = format!("bytes {norm_lanes_bytes}..{}", 2 * norm_lanes_bytes);
    let expected: [Option<(&str, &str, &str)>; 6] = [
        Some(("reduce+partials", "ws.norm_partials", &chunk0)),
        Some(("reduce+partials", "ws.norm_partials", &chunk1)),
        None,
        None,
        Some(("norm-fold", "norm.spec", "bytes 0..1")),
        Some(("norm-fold", "norm.spec", "bytes 0..1")),
    ];

    for (k, (&idx, want)) in waits.iter().zip(expected.iter()).enumerate() {
        let mutant = drop_op(&trace, idx);
        match (verify::check(&mutant), want) {
            (Ok(()), None) => {}
            (Err(msg), Some((label, arena, range))) => {
                assert!(msg.contains("race on"), "wait {k}: {msg}");
                assert!(msg.contains(label), "wait {k} missing label {label}: {msg}");
                assert!(msg.contains(arena), "wait {k} missing arena {arena}: {msg}");
                assert!(msg.contains(range), "wait {k} missing range {range}: {msg}");
            }
            (Ok(()), Some(w)) => panic!("wait {k}: dropped edge not flagged, expected {w:?}"),
            (Err(msg), None) => panic!("wait {k}: benign drop flagged: {msg}"),
        }
    }
}

/// A second writer overlapping a real op's declared write window is a
/// write-write race, reported with the overlap.
#[test]
fn write_write_overlap_is_flagged() {
    let trace = record_fused_trace();
    let mut ops = trace.ops.clone();
    // Stream 1 only ever waited the norm barrier, which happens-before
    // none of the update writes — a rogue params write there races.
    ops.push(TraceOp::Launch {
        stream: 1,
        label: "rogue-writer",
        access: AccessSet::new().write(verify::arena("params", 0), 0..4),
    });
    let mutant = Trace {
        n_streams: trace.n_streams,
        async_mode: trace.async_mode,
        ops,
    };
    let msg = verify::check(&mutant).expect_err("overlapping writers must be flagged");
    assert!(msg.contains("race on"), "{msg}");
    assert!(msg.contains("params"), "{msg}");
    assert!(msg.contains("bytes 0..4"), "{msg}");
    assert!(msg.contains("rogue-writer"), "{msg}");
    assert!(msg.contains("update+gather"), "{msg}");
    assert!(msg.contains("write"), "{msg}");
}

/// Moving a wait ahead of its record is a named forward-edge error.
#[test]
fn wait_before_record_mutant_is_flagged() {
    let trace = record_fused_trace();
    let waits = wait_indices(&trace);
    let first_wait = waits[0];
    // Find that event's record and swap the two ops.
    let ev = match trace.ops[first_wait] {
        TraceOp::Wait { event, .. } => event,
        _ => unreachable!(),
    };
    let rec = trace
        .ops
        .iter()
        .position(|op| matches!(op, TraceOp::Record { event, .. } if *event == ev))
        .expect("waited event has a record");
    assert!(rec < first_wait, "trace must be well-edged before mutation");
    let mut ops = trace.ops.clone();
    ops.swap(rec, first_wait);
    let mutant = Trace {
        n_streams: trace.n_streams,
        async_mode: trace.async_mode,
        ops,
    };
    let msg = verify::check(&mutant).expect_err("forward edge must be flagged");
    assert!(msg.contains("before its record"), "{msg}");
}

/// Recording an already-recorded event violates one-shot semantics.
#[test]
fn reused_event_mutant_is_flagged() {
    let trace = record_fused_trace();
    let (rec_idx, stream, ev) = trace
        .ops
        .iter()
        .enumerate()
        .find_map(|(i, op)| match op {
            TraceOp::Record { stream, event } => Some((i, *stream, *event)),
            _ => None,
        })
        .expect("program records events");
    let mut ops = trace.ops.clone();
    ops.insert(rec_idx + 1, TraceOp::Record { stream, event: ev });
    let mutant = Trace {
        n_streams: trace.n_streams,
        async_mode: trace.async_mode,
        ops,
    };
    let msg = verify::check(&mutant).expect_err("reused event must be flagged");
    assert!(msg.contains("one-shot"), "{msg}");
}
