"""Model size presets, shared (via the artifact manifest) with the rust L3.

``tiny``/``small``/``e2e`` are the *executable* presets — sized so CPU-PJRT
training runs in seconds/minutes. The paper-scale presets (0.5B…32B,
Qwen2.5-style shapes) exist for the memory planner and the performance
simulator on the rust side; they are never lowered to HLO here.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ff: int
    seq_len: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.d_head

    def param_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        """Canonical (name, shape) order — the grad/flat-buffer ABI.

        The rust coordinator reads this order from the manifest; any change
        here is an ABI break caught by the manifest hash.
        """
        shapes: list[tuple[str, tuple[int, ...]]] = [
            ("embed", (self.vocab, self.d_model))]
        for i in range(self.n_layers):
            p = f"layers.{i}."
            shapes += [
                (p + "attn_norm", (self.d_model,)),
                (p + "wq", (self.d_model, self.qkv_dim)),
                (p + "wk", (self.d_model, self.qkv_dim)),
                (p + "wv", (self.d_model, self.qkv_dim)),
                (p + "wo", (self.qkv_dim, self.d_model)),
                (p + "mlp_norm", (self.d_model,)),
                (p + "wgate", (self.d_model, self.d_ff)),
                (p + "wup", (self.d_model, self.d_ff)),
                (p + "wdown", (self.d_ff, self.d_model)),
            ]
        shapes += [
            ("final_norm", (self.d_model,)),
            ("lm_head", (self.d_model, self.vocab)),
        ]
        return shapes

    def n_params(self) -> int:
        return sum(int(__import__("math").prod(s)) for _, s in self.param_shapes())

    def to_dict(self) -> dict:
        return asdict(self)


# Executable presets (lowered to HLO, run by the rust runtime).
TINY = ModelConfig("tiny", vocab=64, d_model=32, n_layers=2, n_heads=2,
                   d_head=16, d_ff=64, seq_len=32)
SMALL = ModelConfig("small", vocab=256, d_model=128, n_layers=4, n_heads=4,
                    d_head=32, d_ff=384, seq_len=128)
E2E = ModelConfig("e2e", vocab=512, d_model=384, n_layers=6, n_heads=6,
                  d_head=64, d_ff=1152, seq_len=256)

EXECUTABLE = {c.name: c for c in (TINY, SMALL, E2E)}

# Paper-scale presets (Qwen2.5-style; planner/simulator only).
PAPER_SCALE = {
    "0.5B": ModelConfig("0.5B", vocab=151936, d_model=896, n_layers=24,
                        n_heads=14, d_head=64, d_ff=4864, seq_len=2048),
    "1.5B": ModelConfig("1.5B", vocab=151936, d_model=1536, n_layers=28,
                        n_heads=12, d_head=128, d_ff=8960, seq_len=2048),
    "3B": ModelConfig("3B", vocab=151936, d_model=2048, n_layers=36,
                      n_heads=16, d_head=128, d_ff=11008, seq_len=2048),
    "7B": ModelConfig("7B", vocab=152064, d_model=3584, n_layers=28,
                      n_heads=28, d_head=128, d_ff=18944, seq_len=2048),
    "14B": ModelConfig("14B", vocab=152064, d_model=5120, n_layers=48,
                       n_heads=40, d_head=128, d_ff=13824, seq_len=2048),
    "32B": ModelConfig("32B", vocab=152064, d_model=5120, n_layers=64,
                       n_heads=40, d_head=128, d_ff=27648, seq_len=2048),
}
