"""Differentiable wrappers (custom_vjp) tying the Pallas kernels into the
L2 JAX model.

Pallas calls have no autodiff rules, so every fused op is exposed as a
``jax.custom_vjp`` whose forward *and* backward are the hand-written
kernels — mirroring the paper, where forward and backward CUDA kernels are
both hand-rolled and autodiff does not exist.

GEMM precision policies (paper §3 "Overview"):
  * ``bf16``      — operands rounded to the bf16 grid, f32 accumulation.
  * ``fp8``       — E4M3 forward, E4M3 activation grads in backward.
  * ``fp8_e5m2``  — E4M3 forward, E5M2 activation grads (the traditional
                    recommendation the paper's Fig. 2 shows to be *worse*).
Weight gradients always accumulate in BF16 (paper: "gradient accumulation
remains in BF16 ... avoids catastrophic cancellation").
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from . import ref
from . import quantize as qk
from . import rmsnorm as rk
from . import swiglu as sk
from . import matmul as mk
from . import cross_entropy as ck

GemmPolicy = Literal["bf16", "fp8", "fp8_e5m2"]


def grad_fmt(policy: GemmPolicy) -> ref.Fp8Format:
    return ref.E5M2 if policy == "fp8_e5m2" else ref.E4M3


# ---------------------------------------------------------------------------
# Precision-policy GEMM: y = x @ w
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def gemm(x, w, policy: GemmPolicy = "bf16"):
    y, _ = _gemm_fwd(x, w, policy)
    return y


def _gemm_fwd(x, w, policy):
    if policy == "bf16":
        xb = ref.round_to_bf16(x)
        wb = ref.round_to_bf16(w)
        y = mk.matmul_scaled(xb, jnp.float32(1.0), wb, jnp.float32(1.0))
    else:
        qx, sx = qk.quantize(x, ref.E4M3)
        qw, sw = qk.quantize(w, ref.E4M3)
        y = mk.matmul_scaled(qx, sx, qw, sw)
    return y, (x, w)


def _gemm_bwd(policy, saved, dy):
    x, w = saved
    if policy == "bf16":
        dyb = ref.round_to_bf16(dy)
        dx = mk.matmul_scaled(dyb, jnp.float32(1.0),
                              ref.round_to_bf16(w).T, jnp.float32(1.0))
        dw = mk.matmul_scaled(ref.round_to_bf16(x).T, jnp.float32(1.0),
                              dyb, jnp.float32(1.0))
    else:
        f = grad_fmt(policy)
        qdy, sdy = qk.quantize(dy, f)
        # TN-only FP8 gemm on consumer cards → explicit fused
        # transpose+quantize of the stationary operands (paper §3).
        qwt, swt = qk.transpose_quantize(w, qk.absmax(w), ref.E4M3)
        dx = mk.matmul_scaled(qdy, sdy, qwt, swt)
        qxt, sxt = qk.transpose_quantize(x, qk.absmax(x), ref.E4M3)
        dw = mk.matmul_scaled(qxt, sxt, qdy, sdy)
    return dx, dw


gemm.defvjp(_gemm_fwd, _gemm_bwd)


# ---------------------------------------------------------------------------
# Fused residual-add + RMSNorm (+absmax side output).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def rmsnorm_residual(x, res, gamma):
    y, nres, amax = rk.rmsnorm_residual(x, res, gamma)
    return y, nres, amax


def _rn_fwd(x, res, gamma):
    y, nres, amax = rk.rmsnorm_residual(x, res, gamma)
    return (y, nres, amax), (nres, gamma)


def _rn_bwd(saved, cots):
    nres, gamma = saved
    dy, dnres, _damax = cots
    dxn, dgamma = rk.rmsnorm_bwd(nres, gamma, dy)
    d = dxn + dnres
    return d, d, dgamma


rmsnorm_residual.defvjp(_rn_fwd, _rn_bwd)


@jax.custom_vjp
def rmsnorm(x, gamma):
    y, _, _ = rk.rmsnorm_residual(x, jnp.zeros_like(x), gamma)
    return y


def _rms_fwd(x, gamma):
    y, _, _ = rk.rmsnorm_residual(x, jnp.zeros_like(x), gamma)
    return y, (x, gamma)


def _rms_bwd(saved, dy):
    x, gamma = saved
    return rk.rmsnorm_bwd(x, gamma, dy)


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# Fused SwiGLU (+absmax).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def swiglu(gate, up):
    y, amax = sk.swiglu(gate, up)
    return y, amax


def _sw_fwd(gate, up):
    y, amax = sk.swiglu(gate, up)
    return (y, amax), (gate, up)


def _sw_bwd(saved, cots):
    gate, up = saved
    dy, _damax = cots
    return sk.swiglu_bwd(gate, up, dy)


swiglu.defvjp(_sw_fwd, _sw_bwd)


# ---------------------------------------------------------------------------
# Chunked fused LM-head + cross-entropy (paper §3.1 "Chunking"):
# never materializes the full [N, V] logits in saved residuals — the
# backward recomputes logits per chunk via the fused CE kernel and
# accumulates dW in BF16.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def lm_head_loss(x, w, targets, n_chunks: int = 4, ignore_index: int = -1):
    loss, _ = _lm_fwd(x, w, targets, n_chunks, ignore_index)
    return loss


def _chunks(n, n_chunks):
    assert n % n_chunks == 0, (n, n_chunks)
    c = n // n_chunks
    return [(i * c, c) for i in range(n_chunks)]


def _lm_fwd(x, w, targets, n_chunks, ignore_index):
    n = x.shape[0]
    loss_sum = jnp.float32(0.0)
    count = jnp.float32(0.0)
    xb = ref.round_to_bf16(x)
    wb = ref.round_to_bf16(w)
    for off, c in _chunks(n, n_chunks):
        xs = jax.lax.dynamic_slice_in_dim(xb, off, c, axis=0)
        ts = jax.lax.dynamic_slice_in_dim(targets, off, c, axis=0)
        logits = mk.matmul_scaled(xs, jnp.float32(1.0), wb, jnp.float32(1.0))
        ls, cnt, _ = ck.cross_entropy(logits, ts, ignore_index)
        loss_sum += ls
        count += cnt
    count = jnp.maximum(count, 1.0)
    return loss_sum / count, (x, w, targets, count)


def _lm_bwd(n_chunks, ignore_index, saved, dloss):
    x, w, targets, count = saved
    n = x.shape[0]
    xb = ref.round_to_bf16(x)
    wb = ref.round_to_bf16(w)
    dx = jnp.zeros_like(x)
    dw = jnp.zeros_like(w)
    scale = dloss / count
    for off, c in _chunks(n, n_chunks):
        xs = jax.lax.dynamic_slice_in_dim(xb, off, c, axis=0)
        ts = jax.lax.dynamic_slice_in_dim(targets, off, c, axis=0)
        logits = mk.matmul_scaled(xs, jnp.float32(1.0), wb, jnp.float32(1.0))
        _, _, dlogits = ck.cross_entropy(logits, ts, ignore_index)
        dlogits = dlogits * scale
        dlb = ref.round_to_bf16(dlogits)
        dxs = mk.matmul_scaled(dlb, jnp.float32(1.0), wb.T, jnp.float32(1.0))
        dws = mk.matmul_scaled(xs.T, jnp.float32(1.0), dlb, jnp.float32(1.0))
        dx = jax.lax.dynamic_update_slice_in_dim(dx, dxs, off, axis=0)
        dw = ref.round_to_bf16(dw + dws)   # BF16 grad accumulation
    return dx, dw, None


lm_head_loss.defvjp(_lm_fwd, _lm_bwd)


# ---------------------------------------------------------------------------
# SDPA. The paper calls cuDNN here (BF16); the model uses the pure-jnp SDPA
# (XLA = our "cuDNN") under jax autodiff, with optional chunking over query
# slices. The Pallas flash kernel is used in the inference artifact.
# ---------------------------------------------------------------------------


def sdpa_chunked(q, k, v, n_chunks: int = 1):
    """Causal SDPA over [B,H,T,D], iterating query slices (§3.1 Chunking)."""
    if n_chunks <= 1:
        return ref.sdpa(q, k, v, causal=True)
    b, h, t, d = q.shape
    assert t % n_chunks == 0
    c = t // n_chunks
    outs = []
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    for i in range(n_chunks):
        qs = q[:, :, i * c:(i + 1) * c, :].astype(jnp.float32)
        kv_len = (i + 1) * c
        ks = k[:, :, :kv_len, :].astype(jnp.float32)
        vs = v[:, :, :kv_len, :].astype(jnp.float32)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qs, ks) * scale
        qpos = i * c + jnp.arange(c)[:, None]
        kpos = jnp.arange(kv_len)[None, :]
        logits = jnp.where(qpos >= kpos, logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        outs.append(jnp.einsum("bhqk,bhkd->bhqd", p, vs))
    return jnp.concatenate(outs, axis=2)
