"""Fused cross-entropy forward+backward Pallas kernel.

Paper §3: "we fuse the forward and backward pass of the cross-entropy loss
into a single kernel [Renee, Liger], avoiding the need to materialize a
huge per-token loss tensor". One pass over a block of rows computes the
loss-sum contribution, the valid-token count, AND d(loss_sum)/dlogits.
The token-mean division happens at the caller, which is what makes the
paper's chunked LM-head (§3.1 "Chunking") correct: the chunk kernels only
know the global count after all chunks ran.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _pick_rows(n: int, target: int = 256) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _ce_kernel(logits_ref, tgt_ref, dlogits_ref, loss_ref, count_ref, *,
               ignore_index, vocab):
    logits = logits_ref[...]
    tgt = tgt_ref[...]
    valid = tgt != ignore_index
    tsafe = jnp.where(valid, tgt, 0)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    z = jnp.sum(e, axis=-1)
    lse = m[:, 0] + jnp.log(z)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
              == tsafe[:, None])
    tl = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    per_tok = jnp.where(valid, lse - tl, 0.0)
    p = e / z[:, None]
    dlogits_ref[...] = jnp.where(
        valid[:, None], p - onehot.astype(jnp.float32), 0.0)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        loss_ref[0] = 0.0
        count_ref[0] = 0.0

    loss_ref[0] += jnp.sum(per_tok)
    count_ref[0] += jnp.sum(valid.astype(jnp.float32))


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  ignore_index: int = -1, block_rows: int = 64):
    """[N, V] logits, [N] int32 targets → (loss_sum, count, dlogits_unscaled)."""
    n, vocab = logits.shape
    br = _pick_rows(n, block_rows)
    dlogits, loss, count = pl.pallas_call(
        functools.partial(_ce_kernel, ignore_index=ignore_index, vocab=vocab),
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, vocab), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br, vocab), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, vocab), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(logits.astype(jnp.float32), targets.astype(jnp.int32))
    return loss[0], count[0], dlogits
