"""Fused SwiGLU Pallas kernels with absmax side output.

Paper §3: "all our non-linearity operators have an additional output
parameter that returns the abs-max of its result" — so the subsequent FP8
quantization needs no extra global reduction. The backward kernel fuses the
silu-derivative math into one pass over (gate, up, dy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _pick_rows(n: int, target: int = 128) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _fwd_kernel(g_ref, u_ref, y_ref, amax_ref):
    g = g_ref[...]
    y = g * jax.nn.sigmoid(g) * u_ref[...]
    y_ref[...] = y

    @pl.when(pl.program_id(0) == 0)
    def _init():
        amax_ref[0] = 0.0

    amax_ref[0] = jnp.maximum(amax_ref[0], jnp.max(jnp.abs(y)))


def swiglu(gate: jax.Array, up: jax.Array, block_rows: int = 512):
    """[N, F] silu(gate)·up; returns (y, absmax(y))."""
    n, f = gate.shape
    br = _pick_rows(n, block_rows)
    y, amax = pl.pallas_call(
        _fwd_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, f), lambda i: (i, 0)),
            pl.BlockSpec((br, f), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, f), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, f), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(gate.astype(jnp.float32), up.astype(jnp.float32))
    return y, amax[0]


def _bwd_kernel(g_ref, u_ref, dy_ref, dg_ref, du_ref):
    g = g_ref[...]
    u = u_ref[...]
    dy = dy_ref[...]
    s = jax.nn.sigmoid(g)
    silu = g * s
    dg_ref[...] = dy * u * (s * (1.0 + g * (1.0 - s)))
    du_ref[...] = dy * silu


def swiglu_bwd(gate: jax.Array, up: jax.Array, dy: jax.Array,
               block_rows: int = 512):
    """Returns (dgate, dup)."""
    n, f = gate.shape
    br = _pick_rows(n, block_rows)
    return pl.pallas_call(
        _bwd_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, f), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((br, f), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((n, f), jnp.float32)] * 2,
        interpret=INTERPRET,
    )(gate.astype(jnp.float32), up.astype(jnp.float32),
      dy.astype(jnp.float32))
