"""Pure-jnp reference oracle for every Pallas kernel in this package.

These functions define the *semantics* the kernels must match bit-for-bit
(quantization codecs, stochastic rounding) or to tight float tolerance
(matmul, norm, attention). pytest/hypothesis in ``python/tests`` sweeps
shapes and dtypes against these.

FP8 note: the paper trains with hardware E4M3/E5M2 tensor cores. We have no
FP8 hardware, so the codecs here are bit-exact *software emulations*: they
take f32 arrays and return f32 arrays whose values lie exactly on the FP8
grid (round-to-nearest-even, saturating).  All HLO stays in f32/u32, which
the xla_extension 0.5.1 CPU runtime is guaranteed to parse and execute.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# FP8 formats (paper §2, §3): E4M3 (bias 7, max 448) and E5M2 (bias 15,
# max 57344). E4M3 is the "fn" variant: no infinities, saturate at max.
# ---------------------------------------------------------------------------


class Fp8Format(NamedTuple):
    name: str
    exp_bits: int
    man_bits: int
    bias: int
    max_val: float


E4M3 = Fp8Format("e4m3", 4, 3, 7, 448.0)
E5M2 = Fp8Format("e5m2", 5, 2, 15, 57344.0)

FORMATS = {"e4m3": E4M3, "e5m2": E5M2}


def round_to_fp8(x: jax.Array, fmt: Fp8Format) -> jax.Array:
    """Round f32 values to the nearest FP8 grid point (RNE, saturating).

    Handles normals and FP8 subnormals; returns f32 holding exact FP8
    values. Zero (and signed zero) maps to zero. NaN propagates.
    """
    x = x.astype(jnp.float32)
    sign = jnp.sign(x)
    a = jnp.abs(x)
    a = jnp.minimum(a, fmt.max_val)  # saturate (absmax scaling → no clip)
    # Unbiased f32 exponent via bit twiddling: floor(log2 a) for normals.
    bits = lax.bitcast_convert_type(a, jnp.uint32)
    e_f32 = (bits >> jnp.uint32(23)).astype(jnp.int32) - 127
    # Effective exponent is clamped below by the min-normal exponent, which
    # makes the same formula cover FP8 subnormals (fixed ulp below 2^(1-bias)).
    e_eff = jnp.maximum(e_f32, 1 - fmt.bias)
    # exact ulp = 2^(e_eff - man_bits), built from bits (jnp.exp2 on CPU
    # is not exactly 2^k for integer k!)
    ulp = lax.bitcast_convert_type(
        ((e_eff - fmt.man_bits + 127) << 23).astype(jnp.uint32), jnp.float32)
    q = jnp.round(a / ulp) * ulp  # jnp.round == round-half-even
    q = jnp.minimum(q, fmt.max_val)
    q = jnp.where(a == 0.0, 0.0, q)
    out = sign * q
    return jnp.where(jnp.isnan(x), jnp.float32(jnp.nan), out)


def round_to_bf16(x: jax.Array) -> jax.Array:
    """RNE f32 -> bf16 grid (returned as f32). Bit-exact to bf16 cast."""
    x = x.astype(jnp.float32)
    bits = lax.bitcast_convert_type(x, jnp.uint32)
    rnd = bits + jnp.uint32(0x7FFF) + ((bits >> jnp.uint32(16)) & jnp.uint32(1))
    out = lax.bitcast_convert_type(rnd & jnp.uint32(0xFFFF0000), jnp.float32)
    return jnp.where(jnp.isnan(x), jnp.float32(jnp.nan), out)


# ---------------------------------------------------------------------------
# Counter-based RNG (paper §3 "Reproducibility"): deterministic pseudo-random
# numbers from (counter, key) with no internal state. murmur3-finalizer mix,
# mirrored exactly in rust/src/precision/philox.rs.
# ---------------------------------------------------------------------------


def counter_rng_u32(counter: jax.Array, key: int) -> jax.Array:
    """Map uint32 counters to uint32 pseudo-random values (stateless)."""
    x = counter.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    x = x ^ jnp.uint32(key & 0xFFFFFFFF)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def stochastic_round_bf16(x: jax.Array, counter_base, key: int) -> jax.Array:
    """Stochastically round f32 -> bf16 grid (as f32), unbiased.

    counter_base: scalar uint32; element i uses counter_base + i (row-major).
    """
    x = x.astype(jnp.float32)
    n = x.size
    idx = jnp.arange(n, dtype=jnp.uint32).reshape(x.shape)
    r = counter_rng_u32(idx + jnp.uint32(counter_base), key)
    bits = lax.bitcast_convert_type(x, jnp.uint32)
    rnd = bits + (r & jnp.uint32(0xFFFF))
    out = lax.bitcast_convert_type(rnd & jnp.uint32(0xFFFF0000), jnp.float32)
    return jnp.where(jnp.isnan(x), jnp.float32(jnp.nan), out)


# ---------------------------------------------------------------------------
# Tensor-level just-in-time absmax scaling (paper §3 "Overview").
# ---------------------------------------------------------------------------


def absmax(x: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def quantize_absmax(x: jax.Array, fmt: Fp8Format):
    """JIT tensor-scaled quantize: returns (q, scale) with x ≈ q * scale.

    q holds FP8-grid values in [-max, max]; scale = amax / fmt.max so the
    largest magnitude maps exactly to the largest representable value.
    An all-zero tensor gets scale 1.
    """
    x = x.astype(jnp.float32)
    amax = absmax(x)
    scale = jnp.where(amax > 0, amax / fmt.max_val, 1.0).astype(jnp.float32)
    q = round_to_fp8(x / scale, fmt)
    return q, scale


def quantize_with_amax(x: jax.Array, amax: jax.Array, fmt: Fp8Format):
    """Quantize with a precomputed absmax (paper: recomputation keeps the
    forward-pass statistics so the global reduction is skipped)."""
    x = x.astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / fmt.max_val, 1.0).astype(jnp.float32)
    return round_to_fp8(x / scale, fmt), scale


def fp8_matmul(x: jax.Array, w: jax.Array, fmt_x: Fp8Format = E4M3,
               fmt_w: Fp8Format = E4M3) -> jax.Array:
    """Reference FP8 GEMM: quantize both operands (JIT absmax), multiply on
    the FP8 grid with f32 accumulation, rescale. Mirrors cuBLAS FP8 TN gemm
    with per-tensor scale factors."""
    qx, sx = quantize_absmax(x, fmt_x)
    qw, sw = quantize_absmax(w, fmt_w)
    acc = jnp.matmul(qx, qw, preferred_element_type=jnp.float32)
    return acc * (sx * sw)


# ---------------------------------------------------------------------------
# Fused ops (paper §3: "we fuse all successive operations that are not
# either a global reduction or involve a matrix multiplication", with absmax
# side outputs).
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x = x.astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)


def rmsnorm_residual(x: jax.Array, res: jax.Array, gamma: jax.Array,
                     eps: float = 1e-6):
    """Fused residual-add + RMSNorm; returns (y, new_res, absmax(y))."""
    new_res = x.astype(jnp.float32) + res.astype(jnp.float32)
    y = rmsnorm(new_res, gamma, eps)
    return y, new_res, absmax(y)


def rmsnorm_bwd(x: jax.Array, gamma: jax.Array, dy: jax.Array,
                eps: float = 1e-6):
    """Analytic RMSNorm backward: returns (dx, dgamma)."""
    x = x.astype(jnp.float32)
    dy = dy.astype(jnp.float32)
    g = gamma.astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    r = lax.rsqrt(ms + eps)
    xhat = x * r
    dxhat = dy * g
    dx = r * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    dgamma = jnp.sum(dy * xhat, axis=tuple(range(x.ndim - 1)))
    return dx, dgamma


def swiglu(gate: jax.Array, up: jax.Array):
    """SwiGLU nonlinearity silu(gate) * up; returns (y, absmax(y))."""
    g = gate.astype(jnp.float32)
    u = up.astype(jnp.float32)
    y = g * jax.nn.sigmoid(g) * u
    return y, absmax(y)


def swiglu_bwd(gate: jax.Array, up: jax.Array, dy: jax.Array):
    g = gate.astype(jnp.float32)
    u = up.astype(jnp.float32)
    dy = dy.astype(jnp.float32)
    s = jax.nn.sigmoid(g)
    silu = g * s
    dsilu = s * (1.0 + g * (1.0 - s))
    return dy * u * dsilu, dy * silu


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True):
    """Scaled dot-product attention, f32, causal. [B,H,T,D] layout."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        t = q.shape[-2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def cross_entropy(logits: jax.Array, targets: jax.Array, ignore_index: int = -1):
    """Fused CE fwd/bwd (Liger-style, paper §3): returns
    (loss_sum, count, dlogits_unscaled).

    dlogits_unscaled is d(sum of per-token loss)/dlogits; callers divide by
    the global valid-token count (which chunked callers only know globally).
    """
    logits = logits.astype(jnp.float32)
    n, vocab = logits.shape
    valid = targets != ignore_index
    tsafe = jnp.where(valid, targets, 0)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    tl = jnp.take_along_axis(logits, tsafe[:, None], axis=-1)[:, 0]
    per_tok = jnp.where(valid, lse - tl, 0.0)
    loss_sum = jnp.sum(per_tok)
    count = jnp.sum(valid).astype(jnp.float32)
    p = jnp.exp(logits - lse[:, None])
    onehot = jax.nn.one_hot(tsafe, vocab, dtype=jnp.float32)
    dlogits = jnp.where(valid[:, None], p - onehot, 0.0)
    return loss_sum, count, dlogits


def adamw_step(p, m, v, g, lr, beta1, beta2, eps, weight_decay, step,
               counter_base, key, stochastic: bool = True):
    """AdamW with bf16-grid moments & master weights via stochastic rounding
    (paper §3.1 "Reduced-precision optimizer states"). All arrays f32 holding
    bf16-grid values; returns (p', m', v')."""
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    t = jnp.asarray(step, dtype=jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t
    mh = m2 / bc1
    vh = v2 / bc2
    upd = mh / (jnp.sqrt(vh) + eps) + weight_decay * p
    p2 = p - lr * upd
    if stochastic:
        n = p.size
        p2 = stochastic_round_bf16(p2, counter_base, key)
        m2 = stochastic_round_bf16(m2, counter_base + n, key ^ 0x6D616D6D)
        v2 = stochastic_round_bf16(v2, counter_base + 2 * n, key ^ 0x76766172)
    return p2, m2, v2


def global_norm(tensors) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(t.astype(jnp.float32) ** 2) for t in tensors))
