"""Fused AdamW Pallas kernel with stochastic rounding to the bf16 grid.

Paper §3.1 "Reduced-precision optimizer states": moments m, v and master
weights are stored in BF16; the f32→bf16 conversion uses stochastic
rounding to stay unbiased, drawing from a counter-based generator so no RNG
state needs to live on device ("Reproducibility" §3). One pass reads
(p, m, v, g), updates Adam moments, applies decoupled weight decay, rounds
all three outputs stochastically.

All buffers are f32 *holding bf16-grid values* (see ref.py FP8 note).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INTERPRET = True


def _pick(n: int, target: int = 1024) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _rng_u32(counter, key):
    x = counter * jnp.uint32(0x9E3779B9)
    x = x ^ jnp.uint32(key)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _sr_bf16(x, counter, key):
    bits = lax.bitcast_convert_type(x, jnp.uint32)
    r = _rng_u32(counter, key) & jnp.uint32(0xFFFF)
    return lax.bitcast_convert_type((bits + r) & jnp.uint32(0xFFFF0000),
                                    jnp.float32)


def _adamw_kernel(scalars_ref, p_ref, m_ref, v_ref, g_ref,
                  po_ref, mo_ref, vo_ref, *, block, n, key):
    lr = scalars_ref[0]
    beta1 = scalars_ref[1]
    beta2 = scalars_ref[2]
    eps = scalars_ref[3]
    wd = scalars_ref[4]
    bc1 = scalars_ref[5]       # 1 - beta1^t, precomputed on host
    bc2 = scalars_ref[6]
    counter_base = lax.bitcast_convert_type(scalars_ref[7], jnp.uint32)

    g = g_ref[...]
    m2 = beta1 * m_ref[...] + (1.0 - beta1) * g
    v2 = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps) + wd * p_ref[...]
    p2 = p_ref[...] - lr * upd

    off = (pl.program_id(0) * block).astype(jnp.uint32)
    idx = jax.lax.iota(jnp.uint32, block) + off + counter_base
    po_ref[...] = _sr_bf16(p2, idx, key)
    mo_ref[...] = _sr_bf16(m2, idx + jnp.uint32(n), key ^ 0x6D616D6D)
    vo_ref[...] = _sr_bf16(v2, idx + jnp.uint32(2 * n), key ^ 0x76766172)


def adamw_step_raw(p, m, v, g, scalars, key: int = 0x11A17,
                   block: int = 4096):
    """AOT entry point: scalars = [lr, beta1, beta2, eps, wd, bc1, bc2,
    counter_bits(f32-bitcast u32)] prepared host-side by the rust
    coordinator (bias correction on CPU, as in the paper)."""
    n = p.shape[0]
    b = _pick(n, block)
    return pl.pallas_call(
        functools.partial(_adamw_kernel, block=b, n=n, key=key),
        grid=(n // b,),
        in_specs=[pl.BlockSpec((8,), lambda i: (0,))]
        + [pl.BlockSpec((b,), lambda i: (i,))] * 4,
        out_specs=[pl.BlockSpec((b,), lambda i: (i,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=INTERPRET,
    )(scalars, p.astype(jnp.float32), m.astype(jnp.float32),
      v.astype(jnp.float32), g.astype(jnp.float32))


def adamw_step(p, m, v, g, lr, beta1, beta2, eps, weight_decay, step,
               counter_base, key: int = 0x11A17, block: int = 1024):
    """Flat [N] AdamW update with SR-to-bf16 state; returns (p', m', v').

    ``step`` is the 1-based optimizer step (for bias correction);
    ``counter_base`` a uint32 scalar that the trainer advances by 3N per
    step so random draws never repeat.
    """
    n = p.shape[0]
    b = _pick(n, block)
    bc1 = 1.0 - beta1 ** jnp.asarray(step, jnp.float32)
    bc2 = 1.0 - beta2 ** jnp.asarray(step, jnp.float32)
    scalars = jnp.stack([
        jnp.asarray(lr, jnp.float32),
        jnp.asarray(beta1, jnp.float32),
        jnp.asarray(beta2, jnp.float32),
        jnp.asarray(eps, jnp.float32),
        jnp.asarray(weight_decay, jnp.float32),
        bc1, bc2,
        lax.bitcast_convert_type(jnp.asarray(counter_base, jnp.uint32),
                                 jnp.float32),
    ])
    return pl.pallas_call(
        functools.partial(_adamw_kernel, block=b, n=n, key=key),
        grid=(n // b,),
        in_specs=[pl.BlockSpec((8,), lambda i: (0,))]
        + [pl.BlockSpec((b,), lambda i: (i,))] * 4,
        out_specs=[pl.BlockSpec((b,), lambda i: (i,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=INTERPRET,
    )(scalars, p.astype(jnp.float32), m.astype(jnp.float32),
      v.astype(jnp.float32), g.astype(jnp.float32))
