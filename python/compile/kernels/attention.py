"""Blocked (flash-style) causal attention forward Pallas kernel.

The paper uses cuDNN for SDPA; this kernel is the in-repo equivalent so the
full stack has no external-kernel dependency. Online-softmax over KV blocks
bounds the workspace to one [bq, bk] tile — the same property the paper
exploits when *chunking* the cuDNN workspace (§3.1 "Chunking"): iterate
over query slices, calling the kernel with a smaller workspace.

TPU adaptation: the CUDA warps-per-row reduction becomes a sequential KV
grid dimension with running (max, sum, acc) carried in the output tiles
(index maps ignore the KV index, keeping them VMEM-resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
NEG_INF = -1e30


def _pick(n: int, target: int) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale, bq, bk, kv_steps, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])
        m_ref[0] = jnp.full_like(m_ref[0], NEG_INF)
        l_ref[0] = jnp.zeros_like(l_ref[0])

    q = q_ref[0]
    k = k_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[0]                                   # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                              # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                     # [bq, 1]
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = o_ref[0] * alpha + jnp.dot(
        p, v_ref[0], preferred_element_type=jnp.float32)
    m_ref[0] = m_new

    @pl.when(ki == kv_steps - 1)
    def _final():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 64, bk: int = 64):
    """[BH, T, D] blocked causal attention; returns [BH, T, D] f32."""
    bh, t, d = q.shape
    bq = _pick(t, bq)
    bk = _pick(t, bk)
    kv_steps = t // bk
    scale = 1.0 / (d ** 0.5)
    out, _, _ = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          kv_steps=kv_steps, causal=causal),
        grid=(bh, t // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, t, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, t, 1), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    return out
