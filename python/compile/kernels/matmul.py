"""Blocked FP8-emulated matmul Pallas kernel.

Stands in for cuBLAS FP8 TN GEMM (paper §3): operands arrive already on the
FP8 grid with per-tensor scales; the kernel multiplies grid values with f32
accumulation and applies ``sx·sw`` once in the epilogue — exactly the
per-tensor-scaled GEMM semantics of cublasLtMatmul with
CUBLASLT_MATMUL_DESC_{A,B}_SCALE_POINTER.

TPU adaptation: the CUDA threadblock tiling becomes an (M/bm, N/bn, K/bk)
BlockSpec grid; K is the innermost (sequential, ordered) grid dimension
accumulating into the output tile, which stays resident in VMEM across K
steps because its index map ignores the K index. interpret=True for CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _pick(n: int, target: int) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _mm_kernel(sx_ref, sw_ref, x_ref, w_ref, o_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] *= sx_ref[0] * sw_ref[0]


def matmul_scaled(qx: jax.Array, sx: jax.Array, qw: jax.Array, sw: jax.Array,
                  bm: int = 256, bn: int = 256, bk: int = 256) -> jax.Array:
    """(qx[M,K] @ qw[K,N]) · (sx·sw) with f32 tile accumulation."""
    m, k = qx.shape
    k2, n = qw.shape
    assert k == k2, (qx.shape, qw.shape)
    bm = _pick(m, bm)
    bn = _pick(n, bn)
    bk = _pick(k, bk)
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(jnp.reshape(sx.astype(jnp.float32), (1,)),
      jnp.reshape(sw.astype(jnp.float32), (1,)),
      qx.astype(jnp.float32), qw.astype(jnp.float32))
