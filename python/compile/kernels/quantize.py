"""Pallas kernels for JIT tensor-level absmax scaling + FP8 quantization.

Paper §3 "Overview": LLMQ uses just-in-time tensor-level absmax scaling —
one kernel performs the global |x| reduction, a second rescales so the
largest magnitude maps to the largest representable FP8 value. On consumer
cards FP8 GEMM only supports the TN layout, so the backward pass needs
explicit transposes, which LLMQ fuses with quantization
(``transpose_quantize``).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA global reduction
(atomics-free two-phase, for determinism) becomes a sequential-grid Pallas
reduction — TPU grids execute in order, so accumulating into the output ref
across grid steps is deterministic by construction. Tiles are sized for
VMEM via BlockSpec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True  # CPU PJRT cannot execute Mosaic custom-calls.


def _round_fp8_block(a: jax.Array, fmt: ref.Fp8Format) -> jax.Array:
    """In-kernel RNE-to-FP8 on a block (same math as ref.round_to_fp8)."""
    sign = jnp.sign(a)
    mag = jnp.minimum(jnp.abs(a), fmt.max_val)
    bits = lax.bitcast_convert_type(mag, jnp.uint32)
    e = (bits >> jnp.uint32(23)).astype(jnp.int32) - 127
    e_eff = jnp.maximum(e, 1 - fmt.bias)
    # exact 2^(e_eff - man_bits) via bit construction (see ref.round_to_fp8)
    ulp = lax.bitcast_convert_type(
        ((e_eff - fmt.man_bits + 127) << 23).astype(jnp.uint32), jnp.float32)
    q = jnp.round(mag / ulp) * ulp
    q = jnp.minimum(q, fmt.max_val)
    q = jnp.where(mag == 0.0, 0.0, q)
    return sign * q


def _pick_block(n: int, target: int = 256) -> int:
    """Largest divisor of n that is <= target (VMEM-sized row block)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# absmax: two-phase deterministic global reduction.
# ---------------------------------------------------------------------------


def _absmax_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[0] = 0.0

    o_ref[0] = jnp.maximum(o_ref[0], jnp.max(jnp.abs(x_ref[...])))


def absmax(x: jax.Array, block_rows: int = 16384) -> jax.Array:
    """Global absmax of a tensor via a sequential-grid Pallas reduction."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    b = _pick_block(n, block_rows)
    grid = n // b
    out = pl.pallas_call(
        _absmax_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=INTERPRET,
    )(flat.astype(jnp.float32))
    return out[0]


# ---------------------------------------------------------------------------
# quantize: scale into the representable range, RNE cast to the FP8 grid.
# The absmax arrives as a scalar operand (paper: recompute passes reuse the
# forward-pass statistics, so no second global reduction is needed).
# ---------------------------------------------------------------------------


def _quantize_kernel(amax_ref, x_ref, q_ref, s_ref, *, fmt: ref.Fp8Format):
    amax = amax_ref[0]
    scale = jnp.where(amax > 0, amax / fmt.max_val, 1.0)
    q_ref[...] = _round_fp8_block(x_ref[...] / scale, fmt)

    @pl.when(pl.program_id(0) == 0)
    def _write_scale():
        s_ref[0] = scale


def quantize_with_amax(x: jax.Array, amax: jax.Array, fmt: ref.Fp8Format,
                       block_rows: int = 16384):
    """Quantize with a known absmax; returns (q, scale) with x ≈ q·scale."""
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    b = _pick_block(n, block_rows)
    q, s = pl.pallas_call(
        functools.partial(_quantize_kernel, fmt=fmt),
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(jnp.reshape(amax.astype(jnp.float32), (1,)), flat)
    return q.reshape(shape), s[0]


def quantize(x: jax.Array, fmt: ref.Fp8Format):
    """JIT absmax quantize (reduction kernel + scale kernel), (q, scale)."""
    return quantize_with_amax(x, absmax(x), fmt)


# ---------------------------------------------------------------------------
# Fused transpose + quantize (paper §3: FP8 gemm on consumer cards is
# TN-only, so the backward operands must be transposed; LLMQ fuses the
# transpose with the quantization to avoid an extra pass over HBM).
# ---------------------------------------------------------------------------


def _transpose_quantize_kernel(amax_ref, x_ref, q_ref, s_ref, *, fmt):
    amax = amax_ref[0]
    scale = jnp.where(amax > 0, amax / fmt.max_val, 1.0)
    q_ref[...] = _round_fp8_block(x_ref[...].T / scale, fmt)

    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _write_scale():
        s_ref[0] = scale


def transpose_quantize(x: jax.Array, amax: jax.Array, fmt: ref.Fp8Format,
                       block: int = 256):
    """Fused x.T quantization for a 2-D tensor; returns (qT, scale)."""
    assert x.ndim == 2
    m, n = x.shape
    bm = _pick_block(m, block)
    bn = _pick_block(n, block)
    q, s = pl.pallas_call(
        functools.partial(_transpose_quantize_kernel, fmt=fmt),
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bm, bn), lambda i, j: (j, i)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(jnp.reshape(amax.astype(jnp.float32), (1,)), x.astype(jnp.float32))
    return q, s[0]
