"""Fused residual-add + RMSNorm Pallas kernel with absmax side output.

Paper §3: "RMS-norm and residual-stream addition are handled in a joint
kernel, which then also returns the abs-max of the RMS-norm" — the absmax
feeds the FP8 quantization of the following matmul input without a second
pass over the data.

The backward kernel implements the analytic RMSNorm gradient with the
paper's determinism rule: no atomics — dgamma is accumulated across the
sequential grid (row blocks), which on TPU (ordered grid) is bitwise
deterministic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INTERPRET = True


def _pick_rows(n: int, target: int = 128) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _fwd_kernel(x_ref, res_ref, g_ref, y_ref, nres_ref, amax_ref, *, eps):
    x = x_ref[...]
    nres = x + res_ref[...]
    ms = jnp.mean(nres * nres, axis=-1, keepdims=True)
    y = nres * lax.rsqrt(ms + eps) * g_ref[...]
    y_ref[...] = y
    nres_ref[...] = nres

    @pl.when(pl.program_id(0) == 0)
    def _init():
        amax_ref[0] = 0.0

    amax_ref[0] = jnp.maximum(amax_ref[0], jnp.max(jnp.abs(y)))


def rmsnorm_residual(x: jax.Array, res: jax.Array, gamma: jax.Array,
                     eps: float = 1e-6, block_rows: int = 512):
    """[N, D] fused (x+res) -> RMSNorm; returns (y, new_res, absmax(y))."""
    n, d = x.shape
    br = _pick_rows(n, block_rows)
    y, nres, amax = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x.astype(jnp.float32), res.astype(jnp.float32),
      gamma.astype(jnp.float32))
    return y, nres, amax[0]


def _bwd_kernel(x_ref, g_ref, dy_ref, dx_ref, dg_ref, *, eps):
    x = x_ref[...]
    dy = dy_ref[...]
    g = g_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    r = lax.rsqrt(ms + eps)
    xhat = x * r
    dxhat = dy * g
    dx_ref[...] = r * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1,
                                               keepdims=True))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dg_ref[...] = jnp.zeros_like(dg_ref)

    dg_ref[...] += jnp.sum(dy * xhat, axis=0)


def rmsnorm_bwd(x: jax.Array, gamma: jax.Array, dy: jax.Array,
                eps: float = 1e-6, block_rows: int = 512):
    """Backward of RMSNorm(x)·gamma wrt pre-norm x; returns (dx, dgamma)."""
    n, d = x.shape
    br = _pick_rows(n, block_rows)
    dx, dg = pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps),
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x.astype(jnp.float32), gamma.astype(jnp.float32),
      dy.astype(jnp.float32))
    return dx, dg
