"""L2: Qwen-style transformer forward/backward in JAX, calling L1 kernels.

Mixed-precision layout mirrors the paper exactly (§3 "Overview"):
  * transformer-block matmuls (QKV, O, gate/up/down) run under the GEMM
    precision policy (bf16 / fp8-E4M3 / fp8 with E5M2 grads);
  * nonlinearities (SwiGLU), SDPA, the embedding and the LM-head, and
    gradient accumulation stay in BF16;
  * fused residual+RMSNorm and SwiGLU kernels emit absmax side outputs
    that would feed delayed-free FP8 quantization of the next GEMM.

This file is build-time only: ``aot.py`` lowers ``train_step`` /
``forward_logits`` to HLO text; python never runs on the request path.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ops, ref

Params = dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Initialization (GPT-2-style scaled init, deterministic from an int seed).
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    key = jax.random.PRNGKey(seed)
    params: Params = {}
    scale_out = 0.02 / (2 * cfg.n_layers) ** 0.5
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("wo", "wdown")):
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            * scale_out)
        else:
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
    # Master weights live on the bf16 grid (paper §3.1).
    return {k: ref.round_to_bf16(v) for k, v in params.items()}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_cache(cfg: ModelConfig, t: int):
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    inv = 1.0 / (cfg.rope_theta ** (
        jnp.arange(0, cfg.d_head, 2, dtype=jnp.float32) / cfg.d_head))
    ang = pos * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, H, T, Dh]; rotate pairs (even, odd)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# Transformer block
# ---------------------------------------------------------------------------


def block(params: Params, i: int, h: jax.Array, res: jax.Array,
          cfg: ModelConfig, policy: ops.GemmPolicy, b: int, t: int,
          attn_chunks: int = 1):
    """One pre-norm block on flattened [B·T, D]; returns (h', res')."""
    p = lambda s: params[f"layers.{i}.{s}"]

    # --- attention half: fused residual+norm feeds policy GEMMs ---
    x, res, _amax = ops.rmsnorm_residual(h, res, p("attn_norm"))
    q = ops.gemm(x, p("wq"), policy)
    k = ops.gemm(x, p("wk"), policy)
    v = ops.gemm(x, p("wv"), policy)

    def heads(z):
        return z.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

    cos, sin = rope_cache(cfg, t)
    qh = apply_rope(heads(q), cos, sin)
    kh = apply_rope(heads(k), cos, sin)
    # SDPA stays BF16 ("cuDNN"); chunked over query slices when configured.
    o = ops.sdpa_chunked(ref.round_to_bf16(qh), ref.round_to_bf16(kh),
                         ref.round_to_bf16(heads(v)), attn_chunks)
    o = o.transpose(0, 2, 1, 3).reshape(b * t, cfg.qkv_dim)
    attn_out = ops.gemm(o, p("wo"), policy)

    # --- MLP half ---
    x, res, _amax = ops.rmsnorm_residual(attn_out, res, p("mlp_norm"))
    gate = ops.gemm(x, p("wgate"), policy)
    up = ops.gemm(x, p("wup"), policy)
    y, _amax = ops.swiglu(gate, up)
    mlp_out = ops.gemm(y, p("wdown"), policy)
    return mlp_out, res


def trunk(params: Params, tokens: jax.Array, cfg: ModelConfig,
          policy: ops.GemmPolicy, attn_chunks: int = 1,
          remat_blocks: bool = False) -> jax.Array:
    """Embedding + all blocks + final norm; returns [B·T, D] hidden."""
    b, t = tokens.shape
    h = params["embed"][tokens.reshape(-1)]          # BF16 embedding lookup
    res = jnp.zeros_like(h)

    blk = block
    if remat_blocks:
        # Paper's "Block" recompute policy: only the FFN residual survives
        # the forward pass; everything else is recomputed in backward.
        blk = jax.checkpoint(block, static_argnums=(1, 4, 5, 6, 7, 8))

    for i in range(cfg.n_layers):
        h, res = blk(params, i, h, res, cfg, policy, b, t, attn_chunks)

    final = ops.rmsnorm(h + res, params["final_norm"])
    return final


def loss_fn(params: Params, tokens: jax.Array, targets: jax.Array,
            cfg: ModelConfig, policy: ops.GemmPolicy,
            lmhead_chunks: int = 4, attn_chunks: int = 1,
            remat_blocks: bool = False) -> jax.Array:
    """Token-mean CE loss via the chunked fused LM-head (never materializes
    full logits in residuals)."""
    h = trunk(params, tokens, cfg, policy, attn_chunks, remat_blocks)
    return ops.lm_head_loss(h, params["lm_head"], targets.reshape(-1),
                            lmhead_chunks)


def forward_logits(params: Params, tokens: jax.Array, cfg: ModelConfig,
                   policy: ops.GemmPolicy = "bf16") -> jax.Array:
    """Inference forward returning [B, T, V] logits (for eval/decoding)."""
    b, t = tokens.shape
    h = trunk(params, tokens, cfg, policy)
    logits = ops.gemm(h, params["lm_head"], "bf16")
    return logits.reshape(b, t, cfg.vocab)


def train_step(params: Params, tokens: jax.Array, targets: jax.Array,
               cfg: ModelConfig, policy: ops.GemmPolicy,
               lmhead_chunks: int = 4, attn_chunks: int = 1,
               remat_blocks: bool = False):
    """Fused fwd+bwd: returns (loss, grads) with grads on the bf16 grid
    (paper: gradient accumulation in BF16)."""
    loss, grads = jax.value_and_grad(loss_fn)(
        params, tokens, targets, cfg, policy, lmhead_chunks, attn_chunks,
        remat_blocks)
    grads = {k: ref.round_to_bf16(v) for k, v in grads.items()}
    return loss, grads
