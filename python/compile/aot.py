"""AOT compile path: lower L2 entry points to HLO *text* artifacts.

HLO text (NOT ``lowered.compiler_ir().serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
the runtime's xla_extension 0.5.1 rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per executable preset (tiny/small/e2e):
  artifacts/<cfg>_train_{bf16,fp8,fp8_e5m2}.hlo.txt   (p.., tok, tgt) -> (loss, g..)
  artifacts/<cfg>_fwd.hlo.txt                         (p.., tok) -> logits
  artifacts/<cfg>_adamw.hlo.txt     per-shard flat AdamW (p,m,v,g,scalars)
  artifacts/<cfg>_init.bin          flat f32 init params (manifest order)
  artifacts/<cfg>_manifest.json     the rust-side ABI: shapes, offsets, meta
  artifacts/quantize_selftest.hlo.txt   (x) -> (q, scale)  runtime check

Run: ``cd python && python -m compile.aot --out ../artifacts`` (Makefile).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model
from .kernels import adamw as adamw_k
from .kernels import quantize as qk, ref

POLICIES = ("bf16", "fp8", "fp8_e5m2")

# Per-preset microbatch size and LM-head/attention chunking used for the
# lowered artifacts (rust grad-accumulates across microbatches).
PRESET_META = {
    "tiny": dict(batch=2, lmhead_chunks=2, attn_chunks=1),
    "small": dict(batch=4, lmhead_chunks=4, attn_chunks=1),
    "e2e": dict(batch=8, lmhead_chunks=4, attn_chunks=1),
}

WORLD = 4          # virtual devices in the multi-GPU coordinator
SHARD_ALIGN = 1024  # flat param buffer padded to WORLD * SHARD_ALIGN


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)", flush=True)


def lower_train(cfg: configs.ModelConfig, policy: str, batch: int,
                lmhead_chunks: int, attn_chunks: int) -> str:
    names = [n for n, _ in cfg.param_shapes()]

    def fn(*args):
        params = dict(zip(names, args[:len(names)]))
        tokens, targets = args[len(names)], args[len(names) + 1]
        loss, grads = model.train_step(
            params, tokens, targets, cfg, policy, lmhead_chunks, attn_chunks)
        return (loss, *[grads[n] for n in names])

    specs = [jax.ShapeDtypeStruct(s, jnp.float32)
             for _, s in cfg.param_shapes()]
    tok = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(*specs, tok, tok))


def lower_fwd(cfg: configs.ModelConfig, batch: int, policy: str = "bf16") -> str:
    names = [n for n, _ in cfg.param_shapes()]

    def fn(*args):
        params = dict(zip(names, args[:len(names)]))
        return (model.forward_logits(params, args[len(names)], cfg, policy),)

    specs = [jax.ShapeDtypeStruct(s, jnp.float32)
             for _, s in cfg.param_shapes()]
    tok = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(*specs, tok))


def lower_adamw(shard_len: int) -> str:
    def fn(p, m, v, g, scalars):
        return adamw_k.adamw_step_raw(p, m, v, g, scalars)

    vec = jax.ShapeDtypeStruct((shard_len,), jnp.float32)
    sc = jax.ShapeDtypeStruct((8,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(vec, vec, vec, vec, sc))


def lower_quantize_selftest(n: int = 4096) -> str:
    def fn(x):
        q, s = qk.quantize(x, ref.E4M3)
        return q, s.reshape(1)

    return to_hlo_text(jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32)))


def flat_layout(cfg: configs.ModelConfig):
    """Flat f32 buffer layout: manifest order, padded to WORLD*SHARD_ALIGN."""
    offsets = []
    off = 0
    for name, shape in cfg.param_shapes():
        n = int(np.prod(shape))
        offsets.append({"name": name, "shape": list(shape),
                        "offset": off, "numel": n})
        off += n
    align = WORLD * SHARD_ALIGN
    padded = (off + align - 1) // align * align
    return offsets, off, padded


def export_preset(cfg: configs.ModelConfig, outdir: str, seed: int) -> None:
    meta = PRESET_META[cfg.name]
    print(f"preset {cfg.name}: {cfg.n_params():,} params, "
          f"batch {meta['batch']}", flush=True)

    for policy in POLICIES:
        _write(os.path.join(outdir, f"{cfg.name}_train_{policy}.hlo.txt"),
               lower_train(cfg, policy, meta["batch"],
                           meta["lmhead_chunks"], meta["attn_chunks"]))
    _write(os.path.join(outdir, f"{cfg.name}_fwd.hlo.txt"),
           lower_fwd(cfg, meta["batch"]))
    # FP8 inference path (Table 6: I → FP8 columns).
    _write(os.path.join(outdir, f"{cfg.name}_fwd_fp8.hlo.txt"),
           lower_fwd(cfg, meta["batch"], "fp8"))

    offsets, total, padded = flat_layout(cfg)
    shard = padded // WORLD
    _write(os.path.join(outdir, f"{cfg.name}_adamw.hlo.txt"),
           lower_adamw(shard))

    # Flat initial parameters (bf16 grid), manifest order.
    params = model.init_params(cfg, seed)
    flat = np.zeros(padded, dtype=np.float32)
    for ent in offsets:
        flat[ent["offset"]:ent["offset"] + ent["numel"]] = \
            np.asarray(params[ent["name"]], dtype=np.float32).ravel()
    init_path = os.path.join(outdir, f"{cfg.name}_init.bin")
    flat.tofile(init_path)
    print(f"  wrote {init_path} ({flat.nbytes / 1e6:.2f} MB)", flush=True)

    manifest = {
        "config": cfg.to_dict(),
        "batch": meta["batch"],
        "lmhead_chunks": meta["lmhead_chunks"],
        "attn_chunks": meta["attn_chunks"],
        "world": WORLD,
        "params": offsets,
        "total_numel": total,
        "padded_numel": padded,
        "shard_numel": shard,
        "policies": list(POLICIES),
        "abi_hash": hashlib.sha256(
            json.dumps(offsets).encode()).hexdigest()[:16],
        "artifacts": {
            **{f"train_{p}": f"{cfg.name}_train_{p}.hlo.txt"
               for p in POLICIES},
            "fwd": f"{cfg.name}_fwd.hlo.txt",
            "fwd_fp8": f"{cfg.name}_fwd_fp8.hlo.txt",
            "adamw": f"{cfg.name}_adamw.hlo.txt",
            "init": f"{cfg.name}_init.bin",
        },
    }
    with open(os.path.join(outdir, f"{cfg.name}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small,e2e")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    _write(os.path.join(args.out, "quantize_selftest.hlo.txt"),
           lower_quantize_selftest())
    for name in args.presets.split(","):
        export_preset(configs.EXECUTABLE[name], args.out, args.seed)
    print("AOT export complete.")


if __name__ == "__main__":
    main()
