"""Property tests for the FP8/BF16 codecs and the counter RNG (ref.py) —
the numeric foundation everything else builds on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

FMTS = [ref.E4M3, ref.E5M2]


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
class TestRoundToFp8:
    def test_exact_values_fixed(self, fmt):
        for v in [0.0, 1.0, -1.0, 0.5, 2.0, fmt.max_val]:
            assert float(ref.round_to_fp8(jnp.float32(v), fmt)) == v

    def test_saturates(self, fmt):
        assert float(ref.round_to_fp8(jnp.float32(1e9), fmt)) == fmt.max_val
        assert float(ref.round_to_fp8(jnp.float32(-1e9), fmt)) == -fmt.max_val

    @settings(max_examples=30, deadline=None)
    @given(st.floats(-1048576.0, 1048576.0, allow_nan=False, width=32))
    def test_idempotent(self, fmt, x):
        q = ref.round_to_fp8(jnp.float32(x), fmt)
        q2 = ref.round_to_fp8(q, fmt)
        assert np.asarray(q).tobytes() == np.asarray(q2).tobytes()

    @settings(max_examples=30, deadline=None)
    @given(st.floats(0.0009765625, 400.0, allow_nan=False, width=32))
    def test_half_ulp_error(self, fmt, x):
        q = float(ref.round_to_fp8(jnp.float32(x), fmt))
        # RNE: |x - q| <= ulp(x)/2 with ulp = 2^(floor(log2 x) - man_bits)
        import math

        e = max(math.floor(math.log2(abs(x))), 1 - fmt.bias)
        ulp = 2.0 ** (e - fmt.man_bits)
        assert abs(x - q) <= ulp / 2 + 1e-12

    @settings(max_examples=40, deadline=None)
    @given(st.floats(0.0009765625, 448.0, width=32))
    def test_sign_symmetry(self, fmt, x):
        qp = float(ref.round_to_fp8(jnp.float32(x), fmt))
        qn = float(ref.round_to_fp8(jnp.float32(-x), fmt))
        assert qp == -qn

    def test_grid_count(self, fmt):
        # Distinct magnitudes on the grid within (0, max]: every code with
        # mantissa+exponent combination reachable by rounding a dense sweep.
        xs = jnp.linspace(-fmt.max_val, fmt.max_val, 400_001)
        q = np.unique(np.asarray(ref.round_to_fp8(xs, fmt)))
        # e.g. E4M3 has ~ 2*(15*8+7) ≈ 253 finite values representable.
        assert 100 < len(q) <= 256


class TestBf16:
    def test_matches_jnp_cast(self):
        xs = np.random.RandomState(0).randn(4096).astype(np.float32) * 100
        ours = np.asarray(ref.round_to_bf16(jnp.asarray(xs)))
        theirs = np.asarray(jnp.asarray(xs).astype(jnp.bfloat16).astype(jnp.float32))
        assert np.array_equal(ours, theirs)

    @settings(max_examples=40, deadline=None)
    @given(st.floats(-1e30, 1e30, allow_nan=False, allow_infinity=False, width=64))
    def test_idempotent(self, x):
        a = float(ref.round_to_bf16(jnp.float32(x)))
        b = float(ref.round_to_bf16(jnp.float32(a)))
        assert a == b or (np.isnan(a) and np.isnan(b))


class TestStochasticRounding:
    def test_unbiased(self):
        x = jnp.full((20000,), 1.00390625, jnp.float32)  # between bf16 points
        out = ref.stochastic_round_bf16(x, 0, 0x11A17)
        assert abs(float(jnp.mean(out)) - 1.00390625) < 1e-4

    def test_deterministic(self):
        x = jnp.asarray(np.random.RandomState(1).randn(256).astype(np.float32))
        a = np.asarray(ref.stochastic_round_bf16(x, 7, 3))
        b = np.asarray(ref.stochastic_round_bf16(x, 7, 3))
        assert np.array_equal(a, b)
        c = np.asarray(ref.stochastic_round_bf16(x, 8, 3))
        assert not np.array_equal(a, c)

    def test_lands_on_grid(self):
        x = jnp.asarray(np.random.RandomState(2).randn(512).astype(np.float32))
        out = ref.stochastic_round_bf16(x, 0, 1)
        grid = ref.round_to_bf16(out)
        assert np.array_equal(np.asarray(out), np.asarray(grid))


class TestQuantizeAbsmax:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 300), st.floats(0.0009765625, 1024.0, width=32))
    def test_amax_maps_to_max(self, n, scale_mag):
        rng = np.random.RandomState(n)
        x = (rng.randn(n) * scale_mag).astype(np.float32)
        q, s = ref.quantize_absmax(jnp.asarray(x), ref.E4M3)
        if np.abs(x).max() > 0:
            assert np.abs(np.asarray(q)).max() == pytest.approx(448.0)
            # reconstruction error bounded by half an ulp of the scale
            err = np.abs(np.asarray(q) * float(s) - x)
            assert err.max() <= float(s) * 448.0 / 8.0

    def test_zero_tensor(self):
        q, s = ref.quantize_absmax(jnp.zeros(16), ref.E4M3)
        assert float(s) == 1.0
        assert np.all(np.asarray(q) == 0)

    def test_known_amax_skips_reduction(self):
        x = jnp.asarray(np.random.RandomState(3).randn(64).astype(np.float32))
        amax = ref.absmax(x)
        q1, s1 = ref.quantize_absmax(x, ref.E4M3)
        q2, s2 = ref.quantize_with_amax(x, amax, ref.E4M3)
        assert np.array_equal(np.asarray(q1), np.asarray(q2))
        assert float(s1) == float(s2)


class TestCounterRng:
    def test_rust_parity_fixture(self):
        # Must match rust/src/precision/philox.rs::parity_fixture
        got = [int(ref.counter_rng_u32(jnp.uint32(c), 0x11A17)) for c in range(4)]
        assert got == [4173432441, 3468058597, 3409582607, 2989545819]

    def test_uniformity(self):
        n = 50000
        vals = np.asarray(
            ref.counter_rng_u32(jnp.arange(n, dtype=jnp.uint32), 9)
        ).astype(np.float64) / 2**32
        assert abs(vals.mean() - 0.5) < 0.01
        hist, _ = np.histogram(vals, bins=16, range=(0, 1))
        assert hist.min() > n / 16 * 0.9
