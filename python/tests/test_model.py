"""L2 model tests: shapes, precision-policy behaviour, chunking
equivalences, and the manifest ABI."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, model
from compile.kernels import ref

CFG = configs.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.RandomState(0)
    tok = jnp.asarray(rng.randint(0, CFG.vocab, (2, CFG.seq_len)))
    tgt = jnp.asarray(rng.randint(0, CFG.vocab, (2, CFG.seq_len)))
    return tok, tgt


def test_param_shapes_cover_all(params):
    names = {n for n, _ in CFG.param_shapes()}
    assert set(params.keys()) == names
    for n, s in CFG.param_shapes():
        assert params[n].shape == s


def test_params_on_bf16_grid(params):
    for n, p in params.items():
        assert np.array_equal(np.asarray(p), np.asarray(ref.round_to_bf16(p))), n


def test_forward_logits_shape_and_finite(params, batch):
    tok, _ = batch
    logits = model.forward_logits(params, tok, CFG)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params, batch):
    # Changing a future token must not change past logits.
    tok, _ = batch
    logits1 = model.forward_logits(params, tok, CFG)
    tok2 = tok.at[:, -1].set((tok[:, -1] + 1) % CFG.vocab)
    logits2 = model.forward_logits(params, tok2, CFG)
    assert_allclose(np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]),
                    atol=1e-5)


@pytest.mark.parametrize("policy", ["bf16", "fp8", "fp8_e5m2"])
def test_train_step_loss_and_grads(params, batch, policy):
    tok, tgt = batch
    loss, grads = model.train_step(params, tok, tgt, CFG, policy)
    # random targets → loss ≈ ln(vocab)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5
    assert set(grads.keys()) == set(params.keys())
    for n, g in grads.items():
        assert g.shape == params[n].shape
        assert bool(jnp.all(jnp.isfinite(g))), n
        # grads arrive on the bf16 grid (paper: bf16 grad accumulation)
        assert np.array_equal(np.asarray(g), np.asarray(ref.round_to_bf16(g))), n


def test_policies_agree_at_init(params, batch):
    tok, tgt = batch
    losses = [float(model.train_step(params, tok, tgt, CFG, p)[0])
              for p in ("bf16", "fp8", "fp8_e5m2")]
    assert max(losses) - min(losses) < 0.05, losses


def test_gradients_match_finite_difference(params, batch):
    # Check one scalar direction of one parameter against central
    # differences through the bf16 policy.
    tok, tgt = batch
    name = "final_norm"
    loss_fn = lambda p: model.loss_fn(p, tok, tgt, CFG, "bf16")
    _, grads = model.train_step(params, tok, tgt, CFG, "bf16")
    eps = 1e-2
    direction = jnp.zeros_like(params[name]).at[3].set(1.0)
    pp = dict(params)
    pp[name] = params[name] + eps * direction
    lp = float(loss_fn(pp))
    pp[name] = params[name] - eps * direction
    lm = float(loss_fn(pp))
    fd = (lp - lm) / (2 * eps)
    an = float(grads[name][3])
    assert abs(fd - an) < max(0.05 * abs(fd), 2e-3), (fd, an)


def test_training_reduces_loss_quickly(params, batch):
    # A few SGD steps on a fixed batch must overfit it (sanity of the
    # whole fwd/bwd pipeline).
    tok, tgt = batch
    p = dict(params)
    first = None
    for _ in range(8):
        loss, grads = model.train_step(p, tok, tgt, CFG, "fp8")
        if first is None:
            first = float(loss)
        p = {k: ref.round_to_bf16(v - 0.5 * grads[k]) for k, v in p.items()}
    final = float(model.train_step(p, tok, tgt, CFG, "fp8")[0])
    assert final < first - 0.2, (first, final)


def test_remat_block_same_loss(params, batch):
    tok, tgt = batch
    a = float(model.loss_fn(params, tok, tgt, CFG, "bf16", remat_blocks=False))
    b = float(model.loss_fn(params, tok, tgt, CFG, "bf16", remat_blocks=True))
    assert abs(a - b) < 1e-5


def test_attention_chunking_equivalent(params, batch):
    tok, tgt = batch
    a = float(model.loss_fn(params, tok, tgt, CFG, "bf16", attn_chunks=1))
    b = float(model.loss_fn(params, tok, tgt, CFG, "bf16", attn_chunks=4))
    assert abs(a - b) < 1e-4


def test_manifest_abi_consistency():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "tiny_manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    man = json.load(open(path))
    shapes = CFG.param_shapes()
    assert len(man["params"]) == len(shapes)
    off = 0
    for ent, (name, shape) in zip(man["params"], shapes):
        assert ent["name"] == name
        assert tuple(ent["shape"]) == shape
        assert ent["offset"] == off
        off += ent["numel"]
    assert man["total_numel"] == off
    assert man["padded_numel"] % man["world"] == 0


def test_rope_rotation_properties():
    cos, sin = model.rope_cache(CFG, 8)
    x = jnp.asarray(np.random.RandomState(0).randn(1, 2, 8, CFG.d_head)
                    .astype(np.float32))
    y = model.apply_rope(x, cos, sin)
    # norm-preserving per pair
    assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                    np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)
    # position 0 unchanged
    assert_allclose(np.asarray(y[:, :, 0]), np.asarray(x[:, :, 0]), atol=1e-6)
