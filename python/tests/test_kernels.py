"""Pallas kernels vs the pure-jnp oracle (ref.py): hypothesis sweeps over
shapes; assert_allclose against ref. The CORE L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (adamw as ak, attention as atk,
                             cross_entropy as ck, matmul as mk, ops,
                             quantize as qk, ref, rmsnorm as rk, swiglu as sk)


def arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.randn(*shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# quantize kernels
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(st.integers(1, 7), st.integers(1, 150), st.integers(0, 2**31))
def test_absmax_kernel_exact(rows, cols, seed):
    rng = np.random.RandomState(seed % 2**31)
    x = arr(rng, rows, cols, scale=10.0)
    assert float(qk.absmax(x)) == float(ref.absmax(x))


@settings(max_examples=5, deadline=None)
@given(st.integers(2, 120), st.sampled_from(["e4m3", "e5m2"]), st.integers(0, 999))
def test_quantize_kernel_matches_ref(n, fmt_name, seed):
    fmt = ref.FORMATS[fmt_name]
    rng = np.random.RandomState(seed)
    x = arr(rng, n, scale=5.0)
    q, s = qk.quantize(x, fmt)
    # grid values must match ref under the kernel's own scale (scale can
    # differ by 1 ulp from eager division)
    exp, _ = ref.quantize_with_amax(x, s * fmt.max_val, fmt)
    assert_allclose(np.asarray(q), np.asarray(exp), rtol=1e-6, atol=1e-7)
    assert np.abs(np.asarray(q)).max() <= fmt.max_val


@settings(max_examples=4, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 999))
def test_transpose_quantize_fused(m, n, seed):
    rng = np.random.RandomState(seed)
    x = arr(rng, m, n, scale=3.0)
    amax = ref.absmax(x)
    qt, s = qk.transpose_quantize(x, amax, ref.E4M3)
    exp, _ = ref.quantize_with_amax(x, amax, ref.E4M3)
    assert qt.shape == (n, m)
    assert_allclose(np.asarray(qt), np.asarray(exp).T, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# fused norm / swiglu
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 65), st.sampled_from([8, 32, 96]), st.integers(0, 999))
def test_rmsnorm_residual_fwd(rows, d, seed):
    rng = np.random.RandomState(seed)
    x, res, g = arr(rng, rows, d), arr(rng, rows, d), arr(rng, d)
    y, nres, amax = rk.rmsnorm_residual(x, res, g)
    yr, nresr, amaxr = ref.rmsnorm_residual(x, res, g)
    assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5)
    assert_allclose(np.asarray(nres), np.asarray(nresr), atol=1e-6)
    assert abs(float(amax) - float(amaxr)) < 2e-5


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 65), st.sampled_from([8, 48]), st.integers(0, 999))
def test_rmsnorm_bwd(rows, d, seed):
    rng = np.random.RandomState(seed)
    x, g, dy = arr(rng, rows, d), arr(rng, d), arr(rng, rows, d)
    dx, dg = rk.rmsnorm_bwd(x, g, dy)
    dxr, dgr = ref.rmsnorm_bwd(x, g, dy)
    assert_allclose(np.asarray(dx), np.asarray(dxr), atol=3e-5)
    assert_allclose(np.asarray(dg), np.asarray(dgr), atol=3e-4)


def test_rmsnorm_bwd_matches_autodiff():
    rng = np.random.RandomState(0)
    x, g = arr(rng, 16, 24), arr(rng, 24)
    dy = arr(rng, 16, 24)
    f = lambda x, g: jnp.sum(ref.rmsnorm(x, g) * dy)
    dxr, dgr = jax.grad(f, argnums=(0, 1))(x, g)
    dx, dg = rk.rmsnorm_bwd(x, g, dy)
    assert_allclose(np.asarray(dx), np.asarray(dxr), atol=2e-5)
    assert_allclose(np.asarray(dg), np.asarray(dgr), atol=2e-4)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 65), st.sampled_from([8, 64]), st.integers(0, 999))
def test_swiglu_fwd_bwd(rows, f, seed):
    rng = np.random.RandomState(seed)
    g, u, dy = arr(rng, rows, f), arr(rng, rows, f), arr(rng, rows, f)
    y, amax = sk.swiglu(g, u)
    yr, amaxr = ref.swiglu(g, u)
    assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5)
    assert abs(float(amax) - float(amaxr)) < 2e-5
    dg, du = sk.swiglu_bwd(g, u, dy)
    dgr, dur = ref.swiglu_bwd(g, u, dy)
    assert_allclose(np.asarray(dg), np.asarray(dgr), atol=2e-5)
    assert_allclose(np.asarray(du), np.asarray(dur), atol=2e-5)


def test_swiglu_bwd_matches_autodiff():
    rng = np.random.RandomState(1)
    g, u, dy = arr(rng, 8, 16), arr(rng, 8, 16), arr(rng, 8, 16)
    f = lambda g, u: jnp.sum(ref.swiglu(g, u)[0] * dy)
    dgr, dur = jax.grad(f, argnums=(0, 1))(g, u)
    dg, du = sk.swiglu_bwd(g, u, dy)
    assert_allclose(np.asarray(dg), np.asarray(dgr), atol=2e-5)
    assert_allclose(np.asarray(du), np.asarray(dur), atol=2e-5)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.integers(1, 48), st.integers(1, 48), st.integers(1, 48),
       st.integers(0, 999))
def test_matmul_scaled_matches_ref(m, k, n, seed):
    rng = np.random.RandomState(seed)
    qx, sx = ref.quantize_absmax(arr(rng, m, k), ref.E4M3)
    qw, sw = ref.quantize_absmax(arr(rng, k, n), ref.E4M3)
    got = mk.matmul_scaled(qx, sx, qw, sw, bm=16, bn=16, bk=16)
    exp = jnp.matmul(qx, qw) * (sx * sw)
    assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-6, atol=1e-5)


def test_fp8_matmul_error_bounded():
    # end-to-end fp8 gemm error vs f32 matmul stays within quantization
    # noise (relative Frobenius error ~ 2-4% for E4M3).
    rng = np.random.RandomState(0)
    x, w = arr(rng, 64, 64), arr(rng, 64, 64)
    exact = np.asarray(jnp.matmul(x, w))
    got = np.asarray(ref.fp8_matmul(x, w))
    rel = np.linalg.norm(got - exact) / np.linalg.norm(exact)
    assert rel < 0.05, rel


# ---------------------------------------------------------------------------
# cross entropy + attention + adamw
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.integers(1, 33), st.sampled_from([11, 64]), st.integers(0, 999))
def test_cross_entropy_kernel(nrows, vocab, seed):
    rng = np.random.RandomState(seed)
    logits = arr(rng, nrows, vocab, scale=3.0)
    tgt = jnp.asarray(rng.randint(0, vocab, nrows))
    tgt = tgt.at[0].set(-1)  # ignore_index
    ls, cnt, dl = ck.cross_entropy(logits, tgt)
    lsr, cntr, dlr = ref.cross_entropy(logits, tgt)
    assert abs(float(ls) - float(lsr)) < 1e-3
    assert float(cnt) == float(cntr)
    assert_allclose(np.asarray(dl), np.asarray(dlr), atol=2e-5)


def test_cross_entropy_grad_is_correct():
    # dlogits/count must equal autodiff gradient of the mean loss
    rng = np.random.RandomState(2)
    logits = arr(rng, 8, 13, scale=2.0)
    tgt = jnp.asarray(rng.randint(0, 13, 8))

    def mean_loss(lg):
        ls, cnt, _ = ref.cross_entropy(lg, tgt)
        return ls / cnt

    gr = jax.grad(mean_loss)(logits)
    _, cnt, dl = ck.cross_entropy(logits, tgt)
    assert_allclose(np.asarray(dl) / float(cnt), np.asarray(gr), atol=2e-5)


@settings(max_examples=3, deadline=None)
@given(st.integers(1, 4), st.sampled_from([16, 32]), st.sampled_from([8, 16]),
       st.integers(0, 99))
def test_flash_attention_vs_ref(bh, t, d, seed):
    rng = np.random.RandomState(seed)
    q, k, v = arr(rng, bh, t, d), arr(rng, bh, t, d), arr(rng, bh, t, d)
    o = atk.flash_attention(q, k, v, bq=8, bk=8)
    orf = ref.sdpa(q[None], k[None], v[None])[0]
    assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-4)


@settings(max_examples=4, deadline=None)
@given(st.integers(4, 600), st.integers(1, 20), st.integers(0, 999))
def test_adamw_kernel_bitexact_vs_ref(n, step, seed):
    rng = np.random.RandomState(seed)
    p = ref.round_to_bf16(arr(rng, n, scale=0.1))
    m = ref.round_to_bf16(arr(rng, n, scale=0.01))
    v = ref.round_to_bf16(jnp.abs(arr(rng, n, scale=0.001)))
    g = ref.round_to_bf16(arr(rng, n, scale=0.05))
    args = (1e-3, 0.9, 0.95, 1e-8, 0.1)
    p1, m1, v1 = ak.adamw_step(p, m, v, g, *args, step, seed % 1000)
    p2, m2, v2 = ref.adamw_step(p, m, v, g, *args, jnp.float32(step),
                                seed % 1000, 0x11A17)
    for a, b in [(p1, p2), (m1, m2), (v1, v2)]:
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# custom_vjp ops
# ---------------------------------------------------------------------------


def test_gemm_policy_gradients_close_to_f32():
    rng = np.random.RandomState(3)
    x, w = arr(rng, 16, 12), arr(rng, 12, 8)
    dy = arr(rng, 16, 8)
    for policy in ["bf16", "fp8", "fp8_e5m2"]:
        f = lambda x, w: jnp.sum(ops.gemm(x, w, policy) * dy)
        dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
        dxr, dwr = jax.grad(lambda x, w: jnp.sum((x @ w) * dy),
                            argnums=(0, 1))(x, w)
        tol = 0.02 if policy == "bf16" else 0.12
        rel = np.linalg.norm(np.asarray(dx) - np.asarray(dxr)) / (
            np.linalg.norm(np.asarray(dxr)) + 1e-9)
        assert rel < tol, (policy, rel)
        rel = np.linalg.norm(np.asarray(dw) - np.asarray(dwr)) / (
            np.linalg.norm(np.asarray(dwr)) + 1e-9)
        assert rel < tol, (policy, rel)


def test_lm_head_loss_chunks_equivalent():
    rng = np.random.RandomState(4)
    x, w = arr(rng, 16, 12), arr(rng, 12, 32)
    tgt = jnp.asarray(rng.randint(0, 32, 16))
    losses = [float(ops.lm_head_loss(x, w, tgt, c)) for c in (1, 2, 4)]
    for l in losses[1:]:
        assert abs(l - losses[0]) < 1e-5

    # grads agree across chunk counts too
    g1 = jax.grad(lambda x, w: ops.lm_head_loss(x, w, tgt, 1), argnums=(0, 1))(x, w)
    g4 = jax.grad(lambda x, w: ops.lm_head_loss(x, w, tgt, 4), argnums=(0, 1))(x, w)
    assert_allclose(np.asarray(g1[0]), np.asarray(g4[0]), atol=2e-4)
    assert_allclose(np.asarray(g1[1]), np.asarray(g4[1]), atol=2e-3)


def test_sdpa_chunked_equivalent():
    rng = np.random.RandomState(5)
    q = arr(rng, 2, 2, 16, 8)
    k = arr(rng, 2, 2, 16, 8)
    v = arr(rng, 2, 2, 16, 8)
    full = ops.sdpa_chunked(q, k, v, 1)
    chunked = ops.sdpa_chunked(q, k, v, 4)
    assert_allclose(np.asarray(full), np.asarray(chunked), atol=1e-5)
