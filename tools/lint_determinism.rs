//! Crate-wide determinism lint for `rust/src/`.
//!
//! LLMQ's central promise is bit-exact reproducibility (docs/NUMERICS.md):
//! every float, every checkpoint CRC, every replayed trace must be
//! identical across runs, thread counts and backends. The easiest way to
//! lose that property is not a numeric bug but an *incidental* source of
//! nondeterminism — iterating a `HashMap`, seeding from the wall clock,
//! or a stochastic-rounding path whose draw is not keyed by element
//! index. This file is a small, dependency-free source lint that rejects
//! those patterns crate-wide; `rust/tests/lint_determinism.rs` includes
//! it via `#[path]` and drives it from `cargo test`, so the lint runs in
//! every CI test job without a separate binary or toolchain component.
//!
//! Rules (comment and string-literal text is stripped before matching):
//!
//! * **R1 `hash-collections`** — `HashMap` / `HashSet` anywhere in a
//!   source file. Hash iteration order is randomized per process, so any
//!   use is guilty until a human vouches for it: files whose uses are
//!   provably order-independent (keyed lookups only, or serialization
//!   through sorted keys) are grandfathered in [`HASH_ALLOWLIST`], each
//!   with a reason. New files should reach for `BTreeMap` / `BTreeSet`.
//! * **R2 `wallclock-randomness`** — `thread_rng`, `from_entropy`,
//!   `rand::random`, `SystemTime`-derived seeds, or a direct `Instant`
//!   read. All randomness in this crate flows from the run config seed
//!   through counter-based generators, and all *timing* flows through
//!   `telemetry::now_ns` — the one sanctioned monotonic-clock reader
//!   ([`CLOCK_ALLOWLIST`]), observation-only by contract (NUMERICS.md):
//!   clock values may be logged, but never fed into a numeric decision.
//! * **R3 `unkeyed-sr`** — a stochastic-rounding function (name contains
//!   `stochastic`, starts with `sr_`, or ends with `_sr`) whose
//!   parameter list carries no counter key (`counter`, `ctr`, or
//!   `rng_draw`). NUMERICS.md Rule 1: every SR draw is keyed by global
//!   element index so lane width, chunking and replay are unobservable.
//! * **R4 `unsafe-outside-backend`** — `unsafe` anywhere except
//!   `precision/backend/`, the one module with an audited safety
//!   contract (SIMD dispatch behind runtime feature detection).
//!
//! The lint is intentionally lexical: no parser, no false comfort. It
//! can over-flag (that is what the allowlist is for) but it cannot be
//! silently defeated by formatting.

// Included via `#[path]` from the test harness; not every helper is
// reachable from every test configuration.
#![allow(dead_code)]

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Rule identifiers, used in findings and allowlist scoping.
pub const R1_HASH_COLLECTIONS: &str = "hash-collections";
pub const R2_WALLCLOCK_RANDOMNESS: &str = "wallclock-randomness";
pub const R3_UNKEYED_SR: &str = "unkeyed-sr";
pub const R4_UNSAFE_OUTSIDE_BACKEND: &str = "unsafe-outside-backend";

/// Files (matched by path suffix, `/`-normalized) exempt from R1, each
/// with the reason a human signed off on the hash-collection use. Keep
/// this list short: the bar for an entry is "no behaviour depends on
/// iteration order".
pub const HASH_ALLOWLIST: &[(&str, &str)] = &[
    (
        "fault/mod.rs",
        "fired-site HashSet is membership-only; never iterated",
    ),
    (
        "util/args.rs",
        "CLI flag map; keyed lookups only, never iterated",
    ),
    (
        "util/json.rs",
        "JSON objects serialize through explicitly sorted keys",
    ),
    (
        "runtime/mod.rs",
        "executable cache; keyed lookups only, never iterated",
    ),
    (
        "runtime/manifest.rs",
        "artifact map round-trips through the sorted JSON serializer",
    ),
    (
        "sim/engine.rs",
        "stream-id interning and per-stream busy totals; read by key",
    ),
    (
        "sim/replay.rs",
        "event-id -> task map; keyed lookups only, never iterated",
    ),
    (
        "data/synth.rs",
        "test-only histogram compared entry-by-key, never iterated for output",
    ),
    (
        "comm/coordinator.rs",
        "per-step tally maps; keyed by step id, never iterated for output",
    ),
];

/// Files (matched by path suffix) allowed to read the monotonic clock
/// (`Instant`) directly. Exactly one entry: the telemetry module owns
/// the crate's clock (`telemetry::now_ns`), and every other timing
/// consumer — exec watchdog, bench harness, comm deadlines, span
/// recorders — goes through it. Clock readings are observation-only
/// (spans, counters, timeouts); they never feed a numeric decision, so
/// bitwise reproducibility is unaffected (pinned by the tracing
/// equivalence suite).
pub const CLOCK_ALLOWLIST: &[(&str, &str)] = &[(
    "telemetry/mod.rs",
    "the single monotonic-clock reader behind telemetry::now_ns; \
     observation-only by contract, never feeds numerics",
)];

/// One lint violation: file, 1-based line, rule id, human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: PathBuf,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file.display(),
            self.line,
            self.rule,
            self.message
        )
    }
}

/// Render findings as one block, for a test assertion message.
pub fn render(findings: &[Finding]) -> String {
    let mut s = format!("{} determinism lint violation(s):\n", findings.len());
    for f in findings {
        s.push_str(&format!("  - {f}\n"));
    }
    s.push_str(
        "fix the source (BTreeMap/BTreeSet, seed-derived counter RNGs, \
         counter-keyed SR, unsafe only in precision::backend) or — for \
         provably order-independent hash-collection uses — add a \
         HASH_ALLOWLIST entry in tools/lint_determinism.rs with a reason",
    );
    s
}

/// Replace comment and string-literal interiors with spaces (newlines
/// kept, so line numbers survive). Handles nested `/* */`, `//` lines,
/// `"…"` with escapes, `r"…"` / `r#"…"#` raw strings, char literals,
/// and leaves lifetimes (`'a`) alone.
pub fn strip_comments_and_strings(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out: Vec<char> = Vec::with_capacity(b.len());
    let mut i = 0usize;
    let n = b.len();
    let keep = |c: char| if c == '\n' { '\n' } else { ' ' };
    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(keep(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // Raw string: r"…" or r#"…"# (any number of #).
        if c == 'r' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '#') {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                // Emit placeholder for the opener, then scan to the
                // matching closer `"` + hashes `#`s.
                for _ in i..=j {
                    out.push(' ');
                }
                i = j + 1;
                'raw: while i < n {
                    if b[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && i + 1 + k < n && b[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                out.push(' ');
                            }
                            i += 1 + hashes;
                            break 'raw;
                        }
                    }
                    out.push(keep(b[i]));
                    i += 1;
                }
                continue;
            }
            // `r` not starting a raw string (e.g. an identifier): fall
            // through to the default arm below.
        }
        // Ordinary string literal.
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    out.push(keep(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // Char literal vs lifetime: `'x'` / `'\n'` are literals; `'a` in
        // `&'a T` (no closing quote right after) is a lifetime.
        if c == '\'' {
            if i + 2 < n && b[i + 1] == '\\' {
                // Escaped char literal: skip to the closing quote.
                out.push(' ');
                i += 1;
                while i < n && b[i] != '\'' {
                    out.push(' ');
                    i += 1;
                }
                if i < n {
                    out.push(' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' {
                out.push(' ');
                out.push(' ');
                out.push(' ');
                i += 3;
                continue;
            }
            // Lifetime (or stray quote): keep as-is.
        }
        out.push(c);
        i += 1;
    }
    out.into_iter().collect()
}

fn word_hit(line: &str, word: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = line[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0
            || !line[..at]
                .chars()
                .next_back()
                .map(|c| c.is_alphanumeric() || c == '_')
                .unwrap_or(false);
        let after = at + word.len();
        let after_ok = after >= line.len()
            || !line[after..]
                .chars()
                .next()
                .map(|c| c.is_alphanumeric() || c == '_')
                .unwrap_or(false);
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

fn norm(path: &Path) -> String {
    path.to_string_lossy().replace('\\', "/")
}

fn on_hash_allowlist(rel: &str) -> Option<&'static str> {
    HASH_ALLOWLIST
        .iter()
        .find(|(suffix, _)| rel.ends_with(suffix))
        .map(|&(_, why)| why)
}

fn on_clock_allowlist(rel: &str) -> Option<&'static str> {
    CLOCK_ALLOWLIST
        .iter()
        .find(|(suffix, _)| rel.ends_with(suffix))
        .map(|&(_, why)| why)
}

/// Does `name` look like a stochastic-rounding entry point?
fn is_sr_name(name: &str) -> bool {
    name.contains("stochastic") || name.starts_with("sr_") || name.ends_with("_sr")
}

/// Lint one file's source. `rel` is the path as reported in findings and
/// matched against the allowlist / backend exemption.
pub fn lint_file(rel: &Path, src: &str) -> Vec<Finding> {
    let clean = strip_comments_and_strings(src);
    let rel_s = norm(rel);
    let in_backend = rel_s.contains("precision/backend/");
    let mut findings = Vec::new();

    let lines: Vec<&str> = clean.lines().collect();
    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        // R1: hash collections.
        if on_hash_allowlist(&rel_s).is_none() {
            for word in ["HashMap", "HashSet"] {
                if word_hit(line, word) {
                    findings.push(Finding {
                        file: rel.to_path_buf(),
                        line: lineno,
                        rule: R1_HASH_COLLECTIONS,
                        message: format!(
                            "{word} has randomized iteration order — use \
                             BTreeMap/BTreeSet, or allowlist this file with a reason"
                        ),
                    });
                }
            }
        }
        // R2: wall-clock / OS-entropy randomness.
        for word in ["thread_rng", "from_entropy", "SystemTime"] {
            if word_hit(line, word) {
                findings.push(Finding {
                    file: rel.to_path_buf(),
                    line: lineno,
                    rule: R2_WALLCLOCK_RANDOMNESS,
                    message: format!(
                        "{word} is nondeterministic — all randomness must \
                         derive from the run-config seed via counter RNGs"
                    ),
                });
            }
        }
        if line.contains("rand::random") {
            findings.push(Finding {
                file: rel.to_path_buf(),
                line: lineno,
                rule: R2_WALLCLOCK_RANDOMNESS,
                message: "rand::random draws from thread-local OS entropy".into(),
            });
        }
        // R2 (clocks): a direct `Instant` read outside the telemetry
        // module. Timing flows through `telemetry::now_ns` so the
        // observation-only clock rule has one enforcement point.
        if on_clock_allowlist(&rel_s).is_none() && word_hit(line, "Instant") {
            findings.push(Finding {
                file: rel.to_path_buf(),
                line: lineno,
                rule: R2_WALLCLOCK_RANDOMNESS,
                message: "Instant reads the wall clock — route timing through \
                          telemetry::now_ns (telemetry/mod.rs is the one \
                          CLOCK_ALLOWLIST entry; clocks are observation-only)"
                    .into(),
            });
        }
        // R4: unsafe outside the audited backend module.
        if !in_backend && word_hit(line, "unsafe") {
            findings.push(Finding {
                file: rel.to_path_buf(),
                line: lineno,
                rule: R4_UNSAFE_OUTSIDE_BACKEND,
                message: "unsafe is confined to precision::backend (the audited \
                          SIMD dispatch layer)"
                    .into(),
            });
        }
    }

    // R3: stochastic-rounding functions must take a counter key. Scan
    // `fn` items and accumulate the parameter list to its closing paren.
    let chars: Vec<char> = clean.chars().collect();
    let mut i = 0usize;
    let mut lineno = 1usize;
    while i < chars.len() {
        if chars[i] == '\n' {
            lineno += 1;
            i += 1;
            continue;
        }
        // Match the token `fn` on a word boundary.
        if chars[i] == 'f'
            && i + 1 < chars.len()
            && chars[i + 1] == 'n'
            && (i == 0 || !(chars[i - 1].is_alphanumeric() || chars[i - 1] == '_'))
            && (i + 2 >= chars.len() || !(chars[i + 2].is_alphanumeric() || chars[i + 2] == '_'))
        {
            let fn_line = lineno;
            let mut j = i + 2;
            while j < chars.len() && chars[j].is_whitespace() {
                if chars[j] == '\n' {
                    lineno += 1;
                }
                j += 1;
            }
            let name_start = j;
            while j < chars.len() && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            let name: String = chars[name_start..j].iter().collect();
            if is_sr_name(&name) {
                // Accumulate the parameter list (balanced parens; the
                // signature may span lines).
                while j < chars.len() && chars[j] != '(' {
                    if chars[j] == '\n' {
                        lineno += 1;
                    }
                    j += 1;
                }
                let mut depth = 0usize;
                let mut sig = String::new();
                while j < chars.len() {
                    let c = chars[j];
                    if c == '\n' {
                        lineno += 1;
                    }
                    if c == '(' {
                        depth += 1;
                    }
                    if depth > 0 {
                        sig.push(c);
                    }
                    if c == ')' {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    j += 1;
                }
                let inner = sig.trim_start_matches('(').trim_end_matches(')').trim();
                let keyed = ["counter", "ctr", "rng_draw"]
                    .iter()
                    .any(|k| sig.contains(k));
                if !inner.is_empty() && !keyed {
                    findings.push(Finding {
                        file: rel.to_path_buf(),
                        line: fn_line,
                        rule: R3_UNKEYED_SR,
                        message: format!(
                            "stochastic-rounding fn `{name}` takes no counter key \
                             (`counter`/`ctr`/`rng_draw`) — SR draws must be keyed \
                             by global element index (NUMERICS.md Rule 1)"
                        ),
                    });
                }
            }
            i = j.max(i + 2);
            continue;
        }
        i += 1;
    }

    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Walk `root` (typically `rust/src/`) and lint every `.rs` file.
/// Findings report paths relative to `root`'s parent so messages read
/// `src/exec/mod.rs:…`. Directory entries are visited in sorted order —
/// the lint practices what it preaches.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = fs::read_dir(&dir)?
            .map(|e| e.map(|e| e.path()))
            .collect::<io::Result<_>>()?;
        entries.sort();
        for path in entries {
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
                let src = fs::read_to_string(&path)?;
                let rel = path.strip_prefix(root.parent().unwrap_or(root)).unwrap_or(&path);
                findings.extend(lint_file(rel, &src));
            }
        }
    }
    findings.sort_by(|a, b| (a.file.clone(), a.line, a.rule).cmp(&(b.file.clone(), b.line, b.rule)));
    Ok(findings)
}
